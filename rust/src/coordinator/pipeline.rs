//! The end-to-end embedding pipeline — the system the paper's tables
//! time: core decomposition → (k0-core extraction) → walk generation →
//! SGNS embedding → mean propagation.
//!
//! Each phase is timed separately because the paper's appendix tables
//! report the breakdown (core decomposition / propagation / embedding).
//!
//! Observability (DESIGN.md §Observability): with `trace_out` set (or
//! a caller-supplied [`Tracer`] via [`run_pipeline_traced`]) every
//! phase emits a span — nested under one root `pipeline` span, with a
//! `skipped` field on phases the config turned off — plus a final
//! `sysmon` event carrying the run's RSS/CPU curves, and
//! [`PipelineOutput::trace_summary`] aggregates per-phase durations.
//!
//! Memory (DESIGN.md §Corpus-streaming): the walk corpus is produced as
//! a [`ShardedCorpus`] and training consumes it as a stream of
//! super-batches — the pipeline never holds the full corpus in one
//! allocation, and with `corpus_budget_mb` set the shards spill to disk
//! so peak corpus RSS is O(budget).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::config::{Backend, Embedder, PipelineConfig};
use crate::coordinator::manifest::{self as jobman, ArtifactRecord, ManifestError, PhaseRecord};
use crate::cores::{core_decomposition, subcore, CoreDecomposition};
use crate::embed::{native, trainer, Embedding};
use crate::graph::Graph;
use crate::obs::faults;
use crate::obs::metrics::Registry;
use crate::obs::sysmon::{Sysmon, CPU_METRIC, RSS_METRIC};
use crate::obs::trace::Tracer;
use crate::propagate::propagate_mean;
use crate::runtime::{Manifest, Runtime};
use crate::util::fsio;
use crate::util::json::Json;
use crate::util::timer::PhaseTimer;
use crate::walks::{
    corewalk, generate_walk_shards, node2vec, CorpusShard, ShardOpts, ShardStats, ShardedCorpus,
    WalkParams, WalkSchedule,
};

/// Phase names used in [`PhaseTimer`] (match the paper's columns).
pub const PHASE_DECOMP: &str = "core_decomposition";
pub const PHASE_WALKS: &str = "walks";
pub const PHASE_TRAIN: &str = "train";
pub const PHASE_PROP: &str = "propagation";
/// Serving-artifact export (only when `export_store` is set).
pub const PHASE_EXPORT: &str = "export";
/// Manifest-only phase: k0-core extraction (cheap, always recomputed;
/// the record certifies completion for the resume decision table).
pub const PHASE_K0: &str = "k0_extract";

/// Everything a pipeline run produces.
pub struct PipelineOutput {
    /// Full-graph embedding (propagated if k0 was set).
    pub embedding: Embedding,
    pub timer: PhaseTimer,
    pub degeneracy: u32,
    /// k0 actually used (clamped to the degeneracy).
    pub k0_used: Option<u32>,
    pub core_size: usize,
    pub n_walks: u64,
    pub n_tokens: u64,
    pub n_pairs: u64,
    /// (pairs, mean loss) checkpoints when the PJRT backend polls loss.
    pub loss_curve: Vec<trainer::LossPoint>,
    /// Corpus residency telemetry: peak resident bytes during walk
    /// generation and how much spilled to disk.
    pub corpus_stats: ShardStats,
    /// Acknowledgement line from the serving daemon when
    /// `notify_daemon` asked the export step to trigger a hot-swap.
    pub daemon_ack: Option<String>,
    /// Per-span `{name: {count, total_us}}` aggregate when the run was
    /// traced (`trace_out` / [`run_pipeline_traced`]); None otherwise.
    pub trace_summary: Option<Json>,
}

impl PipelineOutput {
    /// The paper's "Embedding" column = walk generation + SGNS training.
    pub fn embed_secs(&self) -> f64 {
        self.timer.secs(PHASE_WALKS) + self.timer.secs(PHASE_TRAIN)
    }

    pub fn total_secs(&self) -> f64 {
        self.timer.total_secs()
    }
}

/// Run the full pipeline on `g`. `runtime` is required for
/// [`Backend::Pjrt`] (pass the shared client + manifest). Tracing
/// follows `cfg.trace_out`; callers that already hold a [`Tracer`]
/// (the CLI, which traces graph loading too) use
/// [`run_pipeline_traced`] directly.
pub fn run_pipeline(
    g: &Graph,
    cfg: &PipelineConfig,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<PipelineOutput> {
    let tracer = Tracer::from_trace_out(cfg.trace_out.as_deref())?;
    run_pipeline_traced(g, cfg, runtime, &tracer)
}

/// [`run_pipeline`] with a caller-supplied tracer (which wins over
/// `cfg.trace_out` — the config field only picks the sink in
/// [`run_pipeline`]). Every phase emits a span nested under one root
/// `pipeline` span; phases the config turns off still emit theirs,
/// flagged `skipped`, so trace consumers always see the same six-phase
/// shape. A `sysmon` event with the run's RSS/CPU series lands last.
pub fn run_pipeline_traced(
    g: &Graph,
    cfg: &PipelineConfig,
    runtime: Option<(&Runtime, &Manifest)>,
    tracer: &Tracer,
) -> Result<PipelineOutput> {
    // Fail fast on configs the samplers cannot honor (p/q <= 0,
    // zero-length walks) — config/CLI parsing validates too, but tests
    // and library callers construct `PipelineConfig` directly.
    cfg.validate()?;
    // Crash-safety bookkeeping (`--job-dir`, DESIGN.md §Robustness):
    // sweep temp files orphaned by dead runs, then open (or start) the
    // durable job manifest. A rejected manifest — truncated, tampered,
    // or from a different semantic config — is reported and ignored:
    // resume never trusts stale phase outputs.
    let mut orphans_removed = 0usize;
    if let Some(d) = &cfg.spill_dir {
        orphans_removed += fsio::sweep_orphans(d);
    }
    let mut job = match &cfg.job_dir {
        Some(dir) => {
            let j = Job::open(dir, cfg, g.fingerprint())?;
            orphans_removed += fsio::sweep_orphans(&j.dir);
            orphans_removed += fsio::sweep_orphans(&j.shards_dir());
            Some(j)
        }
        None => None,
    };
    if cfg.job_dir.is_some() || cfg.spill_dir.is_some() {
        eprintln!("pipeline: orphans_removed={orphans_removed}");
    }
    let mut timer = PhaseTimer::new();
    let root = tracer.span_with(
        "pipeline",
        &[
            ("embedder", Json::str(cfg.embedder.name())),
            ("backend", Json::str(cfg.backend.name())),
        ],
    );
    // Resource curves for the whole run, reported as a trace event at
    // the end. The registry is pipeline-local so concurrent runs in one
    // process (tests) never mix their samples.
    let mon_registry = Arc::new(Registry::new());
    let sysmon = tracer
        .enabled()
        .then(|| Sysmon::start(Arc::clone(&mon_registry), Duration::from_millis(50)));

    // Phase 1: core decomposition (needed by CoreWalk scheduling and/or
    // k0-core extraction; the plain DeepWalk baseline skips it, like the
    // paper's baseline rows which have no decomposition column).
    let needs_decomp = cfg.k0.is_some() || matches!(cfg.embedder, Embedder::CoreWalk);
    let decomp: Option<CoreDecomposition> = {
        let _s = tracer.span_with(PHASE_DECOMP, &[("skipped", Json::Bool(!needs_decomp))]);
        if needs_decomp {
            Some(full_decomposition(g, &mut job, &mut timer)?)
        } else {
            None
        }
    };
    let degeneracy = decomp.as_ref().map(|d| d.degeneracy).unwrap_or(0);

    // Phase 2: pick the graph to embed (whole graph or k0-core).
    let (target, core_nodes, k0_used): (Graph, Option<Vec<u32>>, Option<u32>) = match cfg.k0 {
        None => (g.clone(), None, None),
        Some(k0_req) => {
            let d = decomp.as_ref().unwrap();
            let k0 = k0_req.min(d.degeneracy);
            if k0 == 0 {
                bail!("k0=0 requested; use k0=None for the full graph");
            }
            let (sub, map) = subcore::k_core_subgraph(g, d, k0);
            if sub.n_nodes() == 0 {
                bail!("{k0}-core is empty (degeneracy {})", d.degeneracy);
            }
            (sub, Some(map), Some(k0))
        }
    };
    // k0 extraction is cheap and always recomputed; its manifest record
    // is a completion certificate only (resume decision table).
    if let (Some(j), Some(k0)) = (job.as_mut(), k0_used) {
        if j.completed(PHASE_K0).is_none() {
            j.commit(
                PHASE_K0,
                PhaseRecord {
                    info: vec![
                        ("k0_used".into(), k0 as f64),
                        ("core_size".into(), target.n_nodes() as f64),
                    ],
                    ..Default::default()
                },
            )?;
        }
    }

    // Phase 3: walk schedule + corpus on the target graph. With a job
    // dir, a committed walks phase reopens its sealed shard files
    // (checksummed in the manifest) instead of regenerating.
    let mut walks_span = tracer.span(PHASE_WALKS);
    let resumed_corpus: Option<ShardedCorpus> = job.as_ref().and_then(|j| {
        let rec = j.completed(PHASE_WALKS)?;
        if rec.shards.is_empty() {
            return None;
        }
        match ShardedCorpus::open_sealed_dir(&j.shards_dir(), target.n_nodes(), &rec.shards) {
            Ok(c) => {
                eprintln!(
                    "pipeline: resume: skipping {PHASE_WALKS} ({} sealed shards)",
                    rec.shards.len()
                );
                Some(c)
            }
            Err(e) => {
                eprintln!("pipeline: sealed shards unusable ({e:#}); regenerating walks");
                None
            }
        }
    });
    let corpus: ShardedCorpus = match resumed_corpus {
        Some(c) => c,
        None => {
            let schedule = match cfg.embedder {
                Embedder::DeepWalk | Embedder::Node2Vec { .. } => {
                    WalkSchedule::uniform(target.n_nodes(), cfg.walks_per_node)
                }
                Embedder::CoreWalk => {
                    // Core indices *of the embedded graph*: recompute on the
                    // target (for the full graph this equals `decomp`).
                    let d_target = if cfg.k0.is_none() {
                        decomp.clone().unwrap()
                    } else {
                        core_decomposition(&target)
                    };
                    corewalk::corewalk_schedule(&d_target, cfg.walks_per_node)
                }
            };
            let mut shard_opts =
                ShardOpts::with_budget_mb(cfg.corpus_shards, cfg.corpus_budget_mb);
            shard_opts.spill_dir = cfg.spill_dir.clone();
            let mut corpus: ShardedCorpus = timer.time(PHASE_WALKS, || match cfg.embedder {
                // Both walkers are shard-native: walks stream straight through
                // bounded-memory ShardWriters — no materialized corpus, no
                // re-shard copy, peak corpus RSS O(budget) either way.
                Embedder::Node2Vec { p, q } => node2vec::generate_node2vec_shards(
                    &target,
                    &schedule,
                    &node2vec::Node2VecParams {
                        p,
                        q,
                        walk_length: cfg.walk_length,
                        seed: cfg.seed ^ 0xA11CE,
                        threads: cfg.threads,
                    },
                    &shard_opts,
                ),
                _ => generate_walk_shards(
                    &target,
                    &schedule,
                    &WalkParams {
                        walk_length: cfg.walk_length,
                        seed: cfg.seed ^ 0xA11CE,
                        threads: cfg.threads,
                    },
                    &shard_opts,
                ),
            });

            // Phase 3b: bridge walks for disconnected cores (paper §4
            // extension), appended as one extra shard at the end of the
            // canonical order.
            if cfg.bridge_walks > 0 {
                if let Some(map) = &core_nodes {
                    let (bridges, _) = timer.time(PHASE_WALKS, || {
                        let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xB21D);
                        crate::walks::bridge::bridge_walks(
                            g,
                            &target,
                            map,
                            cfg.bridge_walks,
                            cfg.walk_length / 4,
                            &mut rng,
                        )
                    });
                    corpus.push_shard(CorpusShard::from_corpus(bridges));
                }
            }
            // Seal the corpus (bridge shard included) into named,
            // fsynced shard files and commit the phase.
            if let Some(j) = job.as_mut() {
                let metas = corpus.seal_to_dir(&j.shards_dir())?;
                j.commit(
                    PHASE_WALKS,
                    PhaseRecord {
                        shards: metas,
                        info: vec![
                            ("n_walks".into(), corpus.n_walks() as f64),
                            ("n_tokens".into(), corpus.n_tokens() as f64),
                        ],
                        ..Default::default()
                    },
                )?;
            }
            corpus
        }
    };
    let (n_walks, n_tokens) = (corpus.n_walks(), corpus.n_tokens());
    walks_span.field("walks", Json::num(n_walks as f64));
    walks_span.field("tokens", Json::num(n_tokens as f64));
    drop(walks_span);

    // Phase 4: SGNS training on the chosen backend — both consume the
    // sharded corpus as a stream; the full corpus is never concatenated.
    let mut train_span =
        tracer.span_with(PHASE_TRAIN, &[("backend", Json::str(cfg.backend.name()))]);
    let mut sgns = cfg.sgns.clone();
    sgns.seed = cfg.seed ^ 0x7EA1;
    let resumed_train: Option<(Embedding, u64)> = job.as_ref().and_then(|j| {
        let rec = j.completed(PHASE_TRAIN)?;
        let art = rec.artifacts.first()?;
        if !art.verify(&j.dir) {
            return None;
        }
        match read_embedding_artifact(
            &jobman::resolve(&j.dir, &art.path),
            target.n_nodes(),
            sgns.dim,
        ) {
            Ok(emb) => {
                eprintln!("pipeline: resume: skipping {PHASE_TRAIN}");
                Some((emb, rec.info("n_pairs").unwrap_or(0.0) as u64))
            }
            Err(e) => {
                eprintln!("pipeline: train artifact unusable ({e:#}); retraining");
                None
            }
        }
    });
    let (core_embedding, n_pairs, loss_curve) = match resumed_train {
        Some((emb, pairs)) => (emb, pairs, Vec::new()),
        None => {
            let (emb, pairs, curve) = match cfg.backend {
                Backend::Pjrt => {
                    let (rt, manifest) = match runtime {
                        Some(x) => x,
                        None => bail!("PJRT backend requires a Runtime + Manifest"),
                    };
                    let r = timer.time(PHASE_TRAIN, || {
                        trainer::train_pjrt(
                            rt,
                            manifest,
                            &corpus,
                            target.n_nodes(),
                            &sgns,
                            cfg.loss_poll,
                        )
                    })?;
                    (r.w_in, r.n_pairs, r.loss_curve)
                }
                Backend::Native => {
                    // Trainer fan-out is its own knob: `train_threads` (0 =
                    // follow `threads`); 1 routes to the deterministic serial
                    // trainer, >1 runs hogwild over the racy shared matrix
                    // (DESIGN.md §Training). With a job dir the serial
                    // trainer also writes a durable mid-train checkpoint
                    // every `ckpt_every` epochs, so a crash resumes from
                    // the last epoch boundary instead of epoch 0.
                    let train_threads = cfg.train_threads_resolved();
                    let ckpt = job.as_ref().map(|j| native::TrainCkpt {
                        path: j.dir.join(Job::CKPT_FILE),
                        every: cfg.ckpt_every.max(1),
                    });
                    let r = timer.time(PHASE_TRAIN, || {
                        native::train_native_parallel_sharded_ckpt(
                            &corpus,
                            target.n_nodes(),
                            &sgns,
                            train_threads,
                            ckpt.as_ref(),
                        )
                    });
                    (r.w_in, r.n_pairs, Vec::new())
                }
            };
            if let Some(j) = job.as_mut() {
                crate::serve::store::write_store(
                    &j.dir.join(Job::TRAIN_FILE),
                    emb.data(),
                    emb.n(),
                    emb.dim(),
                    None,
                )?;
                let art = ArtifactRecord::capture(&j.dir, Job::TRAIN_FILE)?;
                // The phase is complete; its mid-train checkpoint is spent.
                let _ = std::fs::remove_file(j.dir.join(Job::CKPT_FILE));
                j.commit(
                    PHASE_TRAIN,
                    PhaseRecord {
                        artifacts: vec![art],
                        info: vec![("n_pairs".into(), pairs as f64)],
                        ..Default::default()
                    },
                )?;
            }
            (emb, pairs, curve)
        }
    };
    train_span.field("pairs", Json::num(n_pairs as f64));
    drop(train_span);
    let corpus_stats = corpus.stats();
    drop(corpus); // release shards (and any spill files) before propagation

    // Phase 5: propagation back to the whole graph.
    let embedding = {
        let prop_runs = matches!((&core_nodes, k0_used), (Some(_), Some(_)));
        let _s = tracer.span_with(PHASE_PROP, &[("skipped", Json::Bool(!prop_runs))]);
        match (&core_nodes, k0_used) {
            (Some(map), Some(k0)) => {
                let resumed_prop: Option<Embedding> = job.as_ref().and_then(|j| {
                    let rec = j.completed(PHASE_PROP)?;
                    let art = rec.artifacts.first()?;
                    if !art.verify(&j.dir) {
                        return None;
                    }
                    match read_embedding_artifact(
                        &jobman::resolve(&j.dir, &art.path),
                        g.n_nodes(),
                        sgns.dim,
                    ) {
                        Ok(emb) => {
                            eprintln!("pipeline: resume: skipping {PHASE_PROP}");
                            Some(emb)
                        }
                        Err(e) => {
                            eprintln!("pipeline: prop artifact unusable ({e:#}); repropagating");
                            None
                        }
                    }
                });
                match resumed_prop {
                    Some(emb) => emb,
                    None => {
                        let d = decomp.as_ref().unwrap();
                        let emb = timer
                            .time(PHASE_PROP, || {
                                propagate_mean(g, d, k0, map, &core_embedding, &cfg.propagation)
                            })
                            .0;
                        if let Some(j) = job.as_mut() {
                            crate::serve::store::write_store(
                                &j.dir.join(Job::PROP_FILE),
                                emb.data(),
                                emb.n(),
                                emb.dim(),
                                None,
                            )?;
                            let art = ArtifactRecord::capture(&j.dir, Job::PROP_FILE)?;
                            j.commit(
                                PHASE_PROP,
                                PhaseRecord {
                                    artifacts: vec![art],
                                    info: vec![("ran".into(), 1.0)],
                                    ..Default::default()
                                },
                            )?;
                        }
                        emb
                    }
                }
            }
            _ => {
                // Propagation skipped by config: commit a certificate so
                // the resume decision table still sees the phase.
                if let Some(j) = job.as_mut() {
                    if j.completed(PHASE_PROP).is_none() {
                        j.commit(
                            PHASE_PROP,
                            PhaseRecord {
                                info: vec![("ran".into(), 0.0)],
                                ..Default::default()
                            },
                        )?;
                    }
                }
                core_embedding
            }
        }
    };

    // Phase 6: export the serving artifact — the full-graph embedding
    // plus per-node core numbers, so the query tier never re-decomposes
    // (crate::serve::store). Reuses the phase-1 decomposition when the
    // run computed one.
    {
        let skipped = cfg.export_store.is_none();
        let _s = tracer.span_with(PHASE_EXPORT, &[("skipped", Json::Bool(skipped))]);
        if let Some(path) = &cfg.export_store {
            // Manifest records hold the absolutized export path — the
            // resume run may start from a different working directory.
            let abs = if path.is_absolute() {
                path.clone()
            } else {
                std::env::current_dir()?.join(path)
            };
            let already = job
                .as_ref()
                .and_then(|j| {
                    let rec = j.completed(PHASE_EXPORT)?;
                    let art = rec.artifacts.first()?;
                    (art.path == abs.to_string_lossy() && art.verify(&j.dir)).then_some(())
                })
                .is_some();
            if already {
                eprintln!("pipeline: resume: skipping {PHASE_EXPORT}");
            } else {
                let full_decomp;
                let cores: &[u32] = match &decomp {
                    Some(d) => &d.core,
                    None => {
                        full_decomp = full_decomposition(g, &mut job, &mut timer)?;
                        &full_decomp.core
                    }
                };
                timer.time(PHASE_EXPORT, || {
                    crate::serve::store::write_store(
                        path,
                        embedding.data(),
                        embedding.n(),
                        embedding.dim(),
                        Some(cores),
                    )
                })?;
                if let Some(j) = job.as_mut() {
                    let art = ArtifactRecord::capture(&j.dir, &abs.to_string_lossy())?;
                    j.commit(
                        PHASE_EXPORT,
                        PhaseRecord {
                            artifacts: vec![art],
                            ..Default::default()
                        },
                    )?;
                }
            }
        }
    }

    // Phase 6b: signal a running serving daemon to hot-swap to the
    // artifact just exported (validated above: notify needs export).
    // Non-fatal on failure: a down daemon must not discard a completed
    // training run — the connect itself retries with backoff (inside
    // `notify_swap` → `client_exchange`), and if the daemon still
    // cannot be reached or refuses the swap, the pipeline warns and
    // succeeds, recording `daemon_ack: failed (...)` in the report so
    // the miss is visible, not silent. (`make smoke` still hard-fails
    // a broken notify path: the daemon's answers would not change
    // after the re-export.)
    let daemon_ack = match (&cfg.notify_daemon, &cfg.export_store) {
        (Some(addr), Some(path)) => {
            let addr = crate::serve::server::ServeAddr::parse(addr);
            match crate::serve::server::notify_swap(&addr, path) {
                Ok(ack) => Some(ack),
                Err(e) => {
                    let msg = format!("{e:#}").replace('\n', " ");
                    eprintln!("warning: serving daemon at {addr} not notified: {msg}");
                    Some(format!("failed ({msg})"))
                }
            }
        }
        _ => None,
    };

    // Close out the trace: final resource samples as one event, then
    // the root span, then the per-span aggregate for the caller.
    if let Some(mon) = sysmon {
        mon.stop();
        tracer.event(
            "sysmon",
            &[
                ("rss_bytes", mon_registry.series(RSS_METRIC).to_json()),
                ("cpu_secs", mon_registry.series(CPU_METRIC).to_json()),
            ],
        );
    }
    drop(root);
    tracer.flush()?;
    let trace_summary = tracer.enabled().then(|| tracer.summary_json());

    Ok(PipelineOutput {
        embedding,
        degeneracy,
        k0_used,
        core_size: core_nodes.as_ref().map(|m| m.len()).unwrap_or(g.n_nodes()),
        n_walks,
        n_tokens,
        n_pairs,
        loss_curve,
        corpus_stats,
        daemon_ack,
        trace_summary,
        timer,
    })
}

/// Crash-safe job state (`--job-dir`): the durable manifest plus the
/// directory layout every phase publishes into. All writes go through
/// write-tmp-fsync-rename; the manifest is rewritten (durably) after
/// each phase, so a kill at any instant leaves either the old or the
/// new manifest — never a torn one.
struct Job {
    dir: std::path::PathBuf,
    manifest_file: std::path::PathBuf,
    manifest: jobman::Manifest,
}

impl Job {
    const CORES_FILE: &'static str = "cores.bin";
    const TRAIN_FILE: &'static str = "train.kce";
    const PROP_FILE: &'static str = "prop.kce";
    const CKPT_FILE: &'static str = "train.ckpt";
    const SHARDS_DIR: &'static str = "shards";

    /// `graph_hash` is the input graph's [`Graph::fingerprint`]: the
    /// manifest binds phase outputs to the *(config, graph)* pair, so a
    /// rerun against an updated or different graph in the same job dir
    /// is rejected and starts fresh instead of silently reusing sealed
    /// shards and train artifacts computed from other edges.
    fn open(dir: &std::path::Path, cfg: &PipelineConfig, graph_hash: u64) -> Result<Job> {
        std::fs::create_dir_all(dir.join(Self::SHARDS_DIR))
            .map_err(|e| anyhow::anyhow!("creating job dir {}: {e}", dir.display()))?;
        let manifest_file = jobman::manifest_path(dir);
        let hash = cfg.config_hash();
        let manifest = match jobman::Manifest::load(&manifest_file, hash, graph_hash) {
            Ok(m) => {
                eprintln!(
                    "pipeline: job manifest found ({} completed phases); resuming",
                    m.n_phases()
                );
                m
            }
            Err(ManifestError::Missing) => jobman::Manifest::new(hash, graph_hash, cfg.seed),
            Err(e) => {
                eprintln!("pipeline: manifest rejected ({e}); starting fresh");
                jobman::Manifest::new(hash, graph_hash, cfg.seed)
            }
        };
        Ok(Job {
            dir: dir.to_path_buf(),
            manifest_file,
            manifest,
        })
    }

    fn shards_dir(&self) -> std::path::PathBuf {
        self.dir.join(Self::SHARDS_DIR)
    }

    /// Completed-phase record, if the manifest has one.
    fn completed(&self, phase: &str) -> Option<&PhaseRecord> {
        self.manifest.phase(phase)
    }

    /// Record `phase` complete and make it durable. The crash failpoint
    /// sits right after the fsynced rename: it is the kill site the
    /// crash battery uses for "died at a phase boundary".
    fn commit(&mut self, phase: &str, record: PhaseRecord) -> Result<()> {
        self.manifest.record_phase(phase, record);
        self.manifest.store(&self.manifest_file)?;
        faults::maybe_crash(&format!("pipeline.{phase}.crash"));
        Ok(())
    }

    /// Reload the phase-1 decomposition from a verified `cores.bin`,
    /// or None when the record/artifact is absent or fails its checks.
    fn try_load_decomp(&self, n: usize) -> Option<CoreDecomposition> {
        let rec = self.completed(PHASE_DECOMP)?;
        let art = rec.artifacts.first()?;
        if !art.verify(&self.dir) {
            return None;
        }
        match read_decomp(&jobman::resolve(&self.dir, &art.path), n) {
            Ok(d) => {
                eprintln!("pipeline: resume: skipping {PHASE_DECOMP}");
                Some(d)
            }
            Err(e) => {
                eprintln!("pipeline: cores artifact unusable ({e:#}); recomputing");
                None
            }
        }
    }
}

/// Full-graph decomposition, manifest-aware: a valid `cores.bin` in
/// the job dir short-circuits recomputation; otherwise compute (timed),
/// persist durably and commit the phase record. Also used by the
/// export step's fresh-decomposition fallback so a baseline run with
/// `--export-store` caches its core table too.
fn full_decomposition(
    g: &Graph,
    job: &mut Option<Job>,
    timer: &mut PhaseTimer,
) -> Result<CoreDecomposition> {
    if let Some(j) = job.as_ref() {
        if let Some(d) = j.try_load_decomp(g.n_nodes()) {
            return Ok(d);
        }
    }
    let d = timer.time(PHASE_DECOMP, || core_decomposition(g));
    if let Some(j) = job.as_mut() {
        write_decomp(&j.dir.join(Job::CORES_FILE), &d)?;
        let art = ArtifactRecord::capture(&j.dir, Job::CORES_FILE)?;
        j.commit(
            PHASE_DECOMP,
            PhaseRecord {
                artifacts: vec![art],
                info: vec![("degeneracy".into(), d.degeneracy as f64)],
                ..Default::default()
            },
        )?;
    }
    Ok(d)
}

/// `cores.bin` layout: magic, `n` u64, degeneracy u32, reserved u32,
/// then `core[n]` and `order[n]` as LE u32. Integrity comes from the
/// manifest's size+checksum record, not from the file itself.
const CORES_MAGIC: &[u8; 8] = b"KCECORE\0";

fn write_decomp(path: &std::path::Path, d: &CoreDecomposition) -> Result<()> {
    let n = d.core.len();
    let mut buf = Vec::with_capacity(24 + n * 8);
    buf.extend_from_slice(CORES_MAGIC);
    buf.extend_from_slice(&(n as u64).to_le_bytes());
    buf.extend_from_slice(&d.degeneracy.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes());
    for &c in &d.core {
        buf.extend_from_slice(&c.to_le_bytes());
    }
    for &v in &d.order {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    fsio::write_atomic_durable(path, &buf)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

fn read_decomp(path: &std::path::Path, n_expect: usize) -> Result<CoreDecomposition> {
    let buf = std::fs::read(path)?;
    if buf.len() < 24 || &buf[..8] != CORES_MAGIC {
        bail!("{}: not a cores artifact", path.display());
    }
    let n = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    let degeneracy = u32::from_le_bytes(buf[16..20].try_into().unwrap());
    if n != n_expect || buf.len() != 24 + n * 8 {
        bail!(
            "{}: cores artifact shape mismatch (n={n}, expected {n_expect})",
            path.display()
        );
    }
    let word = |i: usize| u32::from_le_bytes(buf[24 + i * 4..28 + i * 4].try_into().unwrap());
    let core: Vec<u32> = (0..n).map(word).collect();
    let order: Vec<u32> = (0..n).map(|i| word(n + i)).collect();
    Ok(CoreDecomposition {
        core,
        degeneracy,
        order,
    })
}

/// Reload a phase-output embedding from a `.kce` artifact (the store
/// format doubles as the pipeline's phase-output container).
fn read_embedding_artifact(
    path: &std::path::Path,
    n_expect: usize,
    dim_expect: usize,
) -> Result<Embedding> {
    let store = crate::serve::EmbeddingStore::open_in_memory(path)?;
    if store.n() != n_expect || store.dim() != dim_expect {
        bail!(
            "{}: embedding artifact shape mismatch ({}x{}, expected {}x{})",
            path.display(),
            store.n(),
            store.dim(),
            n_expect,
            dim_expect
        );
    }
    let mut data = Vec::with_capacity(n_expect * dim_expect);
    for v in 0..n_expect as u32 {
        data.extend_from_slice(store.row(v));
    }
    Ok(Embedding::from_data(data, n_expect, dim_expect))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{Backend, Embedder};
    use crate::graph::generators;

    fn tiny_cfg() -> PipelineConfig {
        PipelineConfig {
            backend: Backend::Native,
            walks_per_node: 4,
            walk_length: 8,
            sgns: crate::embed::SgnsParams {
                dim: 16,
                window: 2,
                ..Default::default()
            },
            threads: 2,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn baseline_deepwalk_full_graph() {
        let g = generators::holme_kim(120, 3, 0.4, &mut crate::util::rng::Rng::new(1));
        let out = run_pipeline(&g, &tiny_cfg(), None).unwrap();
        assert_eq!(out.embedding.n(), 120);
        assert_eq!(out.k0_used, None);
        assert_eq!(out.core_size, 120);
        assert_eq!(out.n_walks, 480);
        assert!(out.n_pairs > 0);
        // Baseline has no decomposition phase, like the paper's rows.
        assert_eq!(out.timer.secs(PHASE_DECOMP), 0.0);
        assert_eq!(out.timer.secs(PHASE_PROP), 0.0);
        assert!(out.embed_secs() > 0.0);
    }

    #[test]
    fn kcore_pipeline_embeds_core_then_propagates() {
        let g = generators::facebook_like(2);
        let mut cfg = tiny_cfg();
        cfg.k0 = Some(25);
        cfg.walks_per_node = 2;
        let out = run_pipeline(&g, &cfg, None).unwrap();
        assert_eq!(out.embedding.n(), g.n_nodes());
        assert_eq!(out.k0_used, Some(25));
        assert!(out.core_size < g.n_nodes() / 2);
        assert!(out.timer.secs(PHASE_DECOMP) > 0.0);
        assert!(out.timer.secs(PHASE_PROP) > 0.0);
        // Core nodes keep their trained rows; far nodes get propagated
        // values (non-zero within the core's component).
        let d = core_decomposition(&g);
        let some_core_node = (0..g.n_nodes() as u32)
            .find(|&v| d.core[v as usize] >= 25)
            .unwrap();
        let norm: f32 = out
            .embedding
            .row(some_core_node)
            .iter()
            .map(|x| x * x)
            .sum();
        assert!(norm > 0.0);
    }

    #[test]
    fn corewalk_generates_fewer_walks() {
        let g = generators::facebook_like(3);
        let mut dw = tiny_cfg();
        dw.walks_per_node = 6;
        let mut cw = dw.clone();
        cw.embedder = Embedder::CoreWalk;
        let out_dw = run_pipeline(&g, &dw, None).unwrap();
        let out_cw = run_pipeline(&g, &cw, None).unwrap();
        assert!(
            out_cw.n_walks < out_dw.n_walks / 2,
            "corewalk {} vs deepwalk {}",
            out_cw.n_walks,
            out_dw.n_walks
        );
        assert!(out_cw.degeneracy > 0);
    }

    #[test]
    fn train_threads_knob_reaches_the_trainer() {
        // Same seed, train_threads=1 twice: the serial route must make
        // the whole pipeline reproducible even with walk threads > 1.
        let g = generators::holme_kim(80, 3, 0.4, &mut crate::util::rng::Rng::new(9));
        let mut cfg = tiny_cfg();
        cfg.threads = 4;
        cfg.train_threads = 1;
        let a = run_pipeline(&g, &cfg, None).unwrap();
        let b = run_pipeline(&g, &cfg, None).unwrap();
        assert_eq!(a.embedding, b.embedding);
        // And the hogwild route still produces a usable embedding.
        cfg.train_threads = 2;
        let c = run_pipeline(&g, &cfg, None).unwrap();
        assert_eq!(c.embedding.n(), 80);
        assert!(c.n_pairs > 0);
        assert!(c.embedding.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn k0_clamps_to_degeneracy() {
        let g = generators::holme_kim(80, 2, 0.4, &mut crate::util::rng::Rng::new(4));
        let mut cfg = tiny_cfg();
        cfg.k0 = Some(10_000);
        let out = run_pipeline(&g, &cfg, None).unwrap();
        assert_eq!(out.k0_used, Some(out.degeneracy));
    }

    #[test]
    fn pjrt_backend_without_runtime_errors() {
        let g = generators::ring(10);
        let mut cfg = tiny_cfg();
        cfg.backend = Backend::Pjrt;
        assert!(run_pipeline(&g, &cfg, None).is_err());
    }

    #[test]
    fn corpus_stats_reported_and_shard_knob_respected() {
        let g = generators::holme_kim(120, 3, 0.4, &mut crate::util::rng::Rng::new(1));
        let mut cfg = tiny_cfg();
        cfg.corpus_shards = 4;
        let out = run_pipeline(&g, &cfg, None).unwrap();
        assert_eq!(out.embedding.n(), 120);
        assert!(out.corpus_stats.peak_resident_bytes > 0);
        // No budget set: everything stays resident.
        assert_eq!(out.corpus_stats.spilled_shards, 0);
        assert_eq!(out.corpus_stats.spilled_bytes, 0);
    }

    #[test]
    fn export_store_writes_loadable_artifact() {
        let g = generators::holme_kim(80, 3, 0.4, &mut crate::util::rng::Rng::new(2));
        let path = std::env::temp_dir().join(format!(
            "kcore_embed_pipeline_export_{}.kce",
            std::process::id()
        ));
        let mut cfg = tiny_cfg();
        cfg.export_store = Some(path.clone());
        let out = run_pipeline(&g, &cfg, None).unwrap();
        assert!(out.timer.secs(PHASE_EXPORT) > 0.0);
        let store = crate::serve::EmbeddingStore::open_in_memory(&path).unwrap();
        assert_eq!(store.n(), 80);
        assert_eq!(store.dim(), cfg.sgns.dim);
        assert!(store.has_cores());
        // Core table matches a fresh decomposition of the input graph.
        let d = core_decomposition(&g);
        assert_eq!(store.cores(), &d.core[..]);
        // Rows are the pipeline's embedding, bit for bit.
        for v in 0..80u32 {
            assert_eq!(store.row(v), out.embedding.row(v));
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn node2vec_embedder_runs() {
        let g = generators::holme_kim(60, 2, 0.3, &mut crate::util::rng::Rng::new(5));
        let mut cfg = tiny_cfg();
        cfg.embedder = Embedder::Node2Vec { p: 0.5, q: 2.0 };
        let out = run_pipeline(&g, &cfg, None).unwrap();
        assert_eq!(out.embedding.n(), 60);
        assert!(out.n_pairs > 0);
    }

    #[test]
    fn node2vec_pipeline_spills_within_budget() {
        // The acceptance contract for shard-native node2vec: under
        // `--embedder node2vec --corpus-budget-mb 1` the MemGauge peak
        // stays within the budget (plus one in-flight walk per shard)
        // and shards spill — no full-corpus materialization anywhere on
        // the pipeline path.
        let g = generators::holme_kim(600, 3, 0.3, &mut crate::util::rng::Rng::new(6));
        let mut cfg = tiny_cfg();
        cfg.embedder = Embedder::Node2Vec { p: 0.5, q: 2.0 };
        cfg.walks_per_node = 20;
        cfg.walk_length = 30;
        cfg.corpus_budget_mb = 1;
        let out = run_pipeline(&g, &cfg, None).unwrap();
        // ~600*20*30*4 bytes = ~1.4 MiB of tokens against a 1 MiB budget.
        assert!(out.n_tokens * 4 > 1 << 20, "corpus too small to exercise spill");
        let stats = out.corpus_stats;
        assert!(stats.spilled_shards > 0, "no shard spilled: {stats:?}");
        assert!(stats.spilled_bytes > 0);
        let budget = 1usize << 20;
        assert!(
            stats.peak_resident_bytes <= budget + 16 * 1024,
            "peak {} exceeds budget {budget}",
            stats.peak_resident_bytes
        );
        assert_eq!(out.embedding.n(), 600);
        assert!(out.n_pairs > 0);
    }

    #[test]
    #[cfg(unix)]
    fn notify_daemon_without_export_fails_but_dead_daemon_is_nonfatal() {
        let g = generators::ring(10);
        let mut cfg = tiny_cfg();
        cfg.notify_daemon = Some("/tmp/kcore_no_daemon_here.sock".to_string());
        // No export_store: rejected at validation, before any work.
        assert!(run_pipeline(&g, &cfg, None).is_err());
        // With an export but nothing listening: the run must still
        // succeed and keep its outputs — a down daemon costs only the
        // notification (warned, recorded as a failed ack).
        let path = std::env::temp_dir().join(format!(
            "kcore_embed_pipeline_notify_{}.kce",
            std::process::id()
        ));
        cfg.export_store = Some(path.clone());
        let out = run_pipeline(&g, &cfg, None).unwrap();
        let ack = out.daemon_ack.as_deref().expect("failed notify still records an ack");
        assert!(ack.starts_with("failed"), "unreachable daemon -> failed ack, got {ack:?}");
        assert!(path.exists(), "export should land even when notify fails");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn traced_run_emits_every_phase_span_under_one_root() {
        let g = generators::holme_kim(60, 3, 0.4, &mut crate::util::rng::Rng::new(3));
        let tracer = Tracer::in_memory();
        let out = run_pipeline_traced(&g, &tiny_cfg(), None, &tracer).unwrap();

        // Untraced runs stay summary-free; traced runs aggregate.
        assert_eq!(run_pipeline(&g, &tiny_cfg(), None).unwrap().trace_summary, None);
        let summary = out.trace_summary.expect("traced run has a summary");
        assert!(summary.path(&["pipeline", "count"]).is_some());
        assert!(summary.path(&[PHASE_WALKS, "total_us"]).is_some());

        // Every phase span is present exactly once, nested under the
        // root `pipeline` span; skipped phases carry the flag.
        let mut spans: Vec<Json> = Vec::new();
        let mut sysmon_events = 0;
        for line in tracer.lines() {
            let j = Json::parse(&line).unwrap();
            match j.get("kind").and_then(Json::as_str) {
                Some("span") => spans.push(j),
                Some("sysmon") => sysmon_events += 1,
                other => panic!("unexpected trace kind {other:?}"),
            }
        }
        assert_eq!(sysmon_events, 1);
        let root = spans
            .iter()
            .find(|s| s.get("name").and_then(Json::as_str) == Some("pipeline"))
            .expect("root span");
        assert_eq!(root.get("parent"), Some(&Json::Null));
        let root_id = root.get("span").and_then(Json::as_i64).unwrap();
        for phase in [PHASE_DECOMP, PHASE_WALKS, PHASE_TRAIN, PHASE_PROP, PHASE_EXPORT] {
            let matches: Vec<&Json> = spans
                .iter()
                .filter(|s| s.get("name").and_then(Json::as_str) == Some(phase))
                .collect();
            assert_eq!(matches.len(), 1, "phase {phase}");
            let parent = matches[0].get("parent").and_then(Json::as_i64);
            assert_eq!(parent, Some(root_id), "phase {phase} not under root");
        }
        // tiny_cfg has no k0 and no export: those phases are flagged.
        for (phase, skipped) in [(PHASE_DECOMP, true), (PHASE_PROP, true), (PHASE_EXPORT, true)] {
            let s = spans
                .iter()
                .find(|s| s.get("name").and_then(Json::as_str) == Some(phase))
                .unwrap();
            let flag = s.path(&["fields", "skipped"]).and_then(Json::as_bool);
            assert_eq!(flag, Some(skipped), "phase {phase}");
        }
        // The walks span reports its volume.
        let walks = spans
            .iter()
            .find(|s| s.get("name").and_then(Json::as_str) == Some(PHASE_WALKS))
            .unwrap();
        let n = walks.path(&["fields", "walks"]).and_then(Json::as_f64);
        assert_eq!(n, Some(out.n_walks as f64));
    }

    #[test]
    fn job_dir_rejects_manifest_from_different_graph() {
        // The reviewer scenario: same job dir, same semantic config,
        // *different input graph* (the dynamic-graph rerun workflow).
        // The manifest must be rejected — never donate sealed shards or
        // train artifacts across graphs — and the second run must land
        // on exactly the bytes a fresh run of the new graph produces.
        let g1 = generators::holme_kim(120, 3, 0.4, &mut crate::util::rng::Rng::new(1));
        let g2 = generators::holme_kim(90, 3, 0.4, &mut crate::util::rng::Rng::new(2));
        let dir = std::env::temp_dir().join(format!(
            "kcore_embed_pipeline_jobgraph_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = tiny_cfg();
        cfg.train_threads = 1; // deterministic trainer: bytes comparable
        cfg.job_dir = Some(dir.clone());
        run_pipeline(&g1, &cfg, None).unwrap();

        let resumed = run_pipeline(&g2, &cfg, None).unwrap();
        let mut fresh_cfg = cfg.clone();
        fresh_cfg.job_dir = None;
        let fresh = run_pipeline(&g2, &fresh_cfg, None).unwrap();
        assert_eq!(resumed.embedding.n(), 90);
        assert_eq!(resumed.n_walks, fresh.n_walks);
        assert_eq!(
            resumed.embedding, fresh.embedding,
            "rerun on a new graph reused stale job-dir outputs"
        );

        // Same graph again: now the manifest *is* reusable and the
        // walks phase resumes from its sealed shards.
        let again = run_pipeline(&g2, &cfg, None).unwrap();
        assert_eq!(again.embedding, fresh.embedding);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn invalid_configs_rejected_before_running() {
        let g = generators::ring(10);
        let mut cfg = tiny_cfg();
        cfg.embedder = Embedder::Node2Vec { p: 0.0, q: 1.0 };
        assert!(run_pipeline(&g, &cfg, None).is_err());
        cfg.embedder = Embedder::Node2Vec { p: 1.0, q: -2.0 };
        assert!(run_pipeline(&g, &cfg, None).is_err());
        cfg.embedder = Embedder::DeepWalk;
        cfg.walk_length = 0;
        assert!(run_pipeline(&g, &cfg, None).is_err());
    }
}
