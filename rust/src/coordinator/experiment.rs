//! Experiment runner: repeated-trial link-prediction experiments with
//! mean ± std aggregation — the machinery behind every table row the
//! paper reports (5 trials each, §3.1.2).

use anyhow::Result;

use crate::coordinator::config::PipelineConfig;
use crate::coordinator::pipeline::{self, run_pipeline};
use crate::eval::{evaluate_link_prediction, split_edges};
use crate::graph::Graph;
use crate::runtime::{Manifest, Runtime};
use crate::util::rng::Rng;
use crate::util::stats::MeanStd;

/// One row of a paper table, aggregated over trials.
#[derive(Debug, Clone)]
pub struct RowResult {
    pub label: String,
    pub f1: MeanStd,
    pub auc: MeanStd,
    pub total_secs: MeanStd,
    pub decomp_secs: MeanStd,
    pub prop_secs: MeanStd,
    pub embed_secs: MeanStd,
    pub core_size: usize,
    pub n_walks: u64,
    pub n_pairs: u64,
}

impl RowResult {
    pub fn f1_pct(&self) -> f64 {
        self.f1.mean() * 100.0
    }
}

/// A link-prediction experiment: graph + removal fraction + trials.
pub struct Experiment<'a> {
    pub graph: &'a Graph,
    pub remove_frac: f64,
    pub trials: usize,
    pub seed: u64,
    pub runtime: Option<(&'a Runtime, &'a Manifest)>,
}

impl<'a> Experiment<'a> {
    /// Run one pipeline configuration over all trials. Each trial uses
    /// its own edge split and pipeline seed (seed = base ^ trial).
    pub fn run_row(&self, cfg: &PipelineConfig) -> Result<RowResult> {
        let mut f1 = MeanStd::new();
        let mut auc = MeanStd::new();
        let mut total = MeanStd::new();
        let mut decomp = MeanStd::new();
        let mut prop = MeanStd::new();
        let mut embed = MeanStd::new();
        let mut core_size = 0usize;
        let mut n_walks = 0u64;
        let mut n_pairs = 0u64;
        for trial in 0..self.trials {
            let mut rng = Rng::new(self.seed ^ (0xD00D + trial as u64));
            let split = split_edges(self.graph, self.remove_frac, &mut rng);
            let mut cfg_t = cfg.clone();
            cfg_t.seed = self.seed ^ ((trial as u64) << 16);
            let out = run_pipeline(&split.train_graph, &cfg_t, self.runtime)?;
            let res =
                evaluate_link_prediction(self.graph, &split.removed, &out.embedding, &mut rng);
            f1.push(res.f1);
            auc.push(res.auc);
            total.push(out.total_secs());
            decomp.push(out.timer.secs(pipeline::PHASE_DECOMP));
            prop.push(out.timer.secs(pipeline::PHASE_PROP));
            embed.push(out.embed_secs());
            core_size = out.core_size;
            n_walks = out.n_walks;
            n_pairs = out.n_pairs;
        }
        Ok(RowResult {
            label: cfg.label(),
            f1,
            auc,
            total_secs: total,
            decomp_secs: decomp,
            prop_secs: prop,
            embed_secs: embed,
            core_size,
            n_walks,
            n_pairs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{Backend, Embedder};
    use crate::graph::generators;

    fn fast_cfg() -> PipelineConfig {
        PipelineConfig {
            backend: Backend::Native,
            walks_per_node: 4,
            walk_length: 10,
            sgns: crate::embed::SgnsParams {
                dim: 16,
                window: 2,
                ..Default::default()
            },
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn rows_aggregate_trials() {
        let g = generators::holme_kim(200, 4, 0.5, &mut Rng::new(1));
        let exp = Experiment {
            graph: &g,
            remove_frac: 0.1,
            trials: 3,
            seed: 7,
            runtime: None,
        };
        let row = exp.run_row(&fast_cfg()).unwrap();
        assert_eq!(row.label, "DeepWalk");
        assert_eq!(row.f1.count(), 3);
        assert!(row.f1.mean() > 0.0 && row.f1.mean() <= 1.0);
        assert!(row.total_secs.mean() > 0.0);
        assert_eq!(row.core_size, 200);
        // F1 should comfortably beat chance on a clustered graph.
        assert!(row.f1.mean() > 0.5, "f1 {}", row.f1.mean());
    }

    #[test]
    fn corewalk_row_runs_with_k0() {
        let g = generators::facebook_like(9);
        let exp = Experiment {
            graph: &g,
            remove_frac: 0.1,
            trials: 2,
            seed: 3,
            runtime: None,
        };
        let mut cfg = fast_cfg();
        cfg.embedder = Embedder::CoreWalk;
        cfg.k0 = Some(49);
        cfg.walks_per_node = 3;
        let row = exp.run_row(&cfg).unwrap();
        assert_eq!(row.label, "49-core (Cw)");
        assert!(row.core_size > 0 && row.core_size < 4039);
        assert!(row.decomp_secs.mean() > 0.0);
        assert!(row.prop_secs.mean() > 0.0);
    }
}
