//! L3 coordination: the experiment pipeline (decompose → extract core →
//! walk → train → propagate), repeated-trial experiment runner, report
//! rendering and the table/figure bench harness.

pub mod bench;
pub mod config;
pub mod experiment;
pub mod manifest;
pub mod pipeline;
pub mod report;

pub use config::{Backend, Embedder, PipelineConfig};
pub use pipeline::{run_pipeline, run_pipeline_traced, PipelineOutput};
