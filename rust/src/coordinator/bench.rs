//! Bench harness: regenerates every table and figure of the paper's
//! evaluation section (see DESIGN.md §Experiment-index).
//!
//! Each benchmark renders a paper-style table/plot to stdout AND writes
//! `<name>.txt` / `<name>.csv` into the output directory, so
//! EXPERIMENTS.md can quote the artifacts directly.
//!
//! Datasets are the calibrated synthetic stand-ins (offline testbed; see
//! DESIGN.md §Substitutions). Absolute seconds differ from the paper's
//! hardware — the reproduced quantities are the *shapes*: who wins, the
//! speedup growth with k0, the bounded F1 drop, the breakdown dominance
//! of embedding time.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::coordinator::config::{Backend, Embedder, PipelineConfig};
use crate::coordinator::experiment::Experiment;
use crate::coordinator::pipeline::run_pipeline;
use crate::coordinator::report::render_table;
use crate::cores::{core_decomposition, subcore};
use crate::embed::SgnsParams;
use crate::graph::{generators, Graph};
use crate::runtime::{Manifest, Runtime};
use crate::util::plot::{ascii_plot, series_csv, Series};
use crate::util::stats::Pca;
use crate::util::table::Table;
use crate::walks::corewalk;

/// Harness options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub trials: usize,
    /// The paper's n (walks per node); 15 in the paper.
    pub walks_per_node: u32,
    pub backend: Backend,
    pub seed: u64,
    pub threads: usize,
    pub out_dir: PathBuf,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            trials: 5,
            walks_per_node: 15,
            backend: Backend::Native,
            seed: 7,
            threads: crate::util::pool::default_threads(),
            out_dir: PathBuf::from("bench_out"),
        }
    }
}

impl BenchOpts {
    /// Reduced-scale settings for `cargo bench` smoke runs.
    pub fn quick() -> Self {
        BenchOpts {
            trials: 2,
            walks_per_node: 5,
            ..Default::default()
        }
    }

    fn base_config(&self) -> PipelineConfig {
        PipelineConfig {
            backend: self.backend,
            walks_per_node: self.walks_per_node,
            walk_length: 30,
            sgns: SgnsParams::default(), // dim 128, window 4, K 5
            threads: self.threads,
            seed: self.seed,
            ..Default::default()
        }
    }
}

/// All recognized benchmark names. `ablate-*` are design-choice ablations
/// beyond the paper's own tables (DESIGN.md §Experiment-index).
pub const BENCH_NAMES: &[&str] = &[
    "table1", "table2", "table3", "table4", "table6", "table8", "table10", "fig1", "fig2",
    "fig3", "fig4", "fig5", "fig6", "coredist", "ablate-op", "ablate-bridge", "ablate-walks",
    "all",
];

/// Entry point: run one named benchmark (or "all").
pub fn run_bench(
    name: &str,
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    std::fs::create_dir_all(&opts.out_dir)
        .with_context(|| format!("creating {}", opts.out_dir.display()))?;
    let out = match name {
        "table1" => bench_core_table(
            "table1",
            "Table 1/5: Link prediction on Cora-like graph, 10% of edges removed (K-core(Dw))",
            "cora",
            0.10,
            Embedder::DeepWalk,
            &[2, 3],
            opts,
            runtime,
        )?,
        "table6" => bench_core_table(
            "table6",
            "Table 6: Link prediction on Cora-like graph, 30% of edges removed (K-core(Dw))",
            "cora",
            0.30,
            Embedder::DeepWalk,
            &[2, 3],
            opts,
            runtime,
        )?,
        "table2" => bench_facebook_table("table2", 0.10, opts, runtime)?,
        "table3" => bench_core_table(
            "table3",
            "Table 3: Link prediction on Facebook-like graph, 10% removed — CoreWalk rows (K-core(Cw))",
            "facebook",
            0.10,
            Embedder::CoreWalk,
            &[9, 25, 49, 73, 97],
            opts,
            runtime,
        )?,
        "table8" => bench_facebook_table("table8", 0.30, opts, runtime)?,
        "table4" => bench_core_table(
            "table4",
            "Table 4/9: Link prediction on Github-like graph, 10% removed (K-core(Dw))",
            "github",
            0.10,
            Embedder::DeepWalk,
            &[10, 20, 30],
            opts,
            runtime,
        )?,
        "table10" => bench_core_table(
            "table10",
            "Table 10: Link prediction on Github-like graph, 30% removed (K-core(Dw))",
            "github",
            0.30,
            Embedder::DeepWalk,
            &[10, 20],
            opts,
            runtime,
        )?,
        "fig1" => bench_fig1(opts)?,
        "fig2" => bench_fig23("fig2", 0.10, opts, runtime)?,
        "fig3" => bench_fig23("fig3", 0.30, opts, runtime)?,
        "fig4" => bench_fig4(opts, runtime)?,
        "fig5" => bench_fig56("fig5", true, opts, runtime)?,
        "fig6" => bench_fig56("fig6", false, opts, runtime)?,
        "coredist" => bench_coredist(opts)?,
        "ablate-op" => bench_ablate_op(opts, runtime)?,
        "ablate-bridge" => bench_ablate_bridge(opts, runtime)?,
        "ablate-walks" => bench_ablate_walks(opts, runtime)?,
        "all" => {
            let mut all = String::new();
            for n in BENCH_NAMES.iter().filter(|&&n| n != "all") {
                all.push_str(&run_bench(n, opts, runtime)?);
                all.push('\n');
            }
            return Ok(all);
        }
        _ => bail!("unknown benchmark {name:?}; known: {BENCH_NAMES:?}"),
    };
    Ok(out)
}

fn graph_by_name(name: &str, seed: u64) -> Result<Graph> {
    generators::by_name(name, seed).ok_or_else(|| anyhow::anyhow!("unknown graph {name:?}"))
}

fn write_out(opts: &BenchOpts, name: &str, text: &str, csv: Option<&str>) -> Result<()> {
    std::fs::write(opts.out_dir.join(format!("{name}.txt")), text)?;
    if let Some(c) = csv {
        std::fs::write(opts.out_dir.join(format!("{name}.csv")), c)?;
    }
    Ok(())
}

/// Shared machinery: DeepWalk baseline + k0-core sweep for one embedder.
#[allow(clippy::too_many_arguments)]
fn bench_core_table(
    name: &str,
    title: &str,
    graph: &str,
    frac: f64,
    embedder: Embedder,
    cores: &[u32],
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    let g = graph_by_name(graph, opts.seed)?;
    let exp = Experiment {
        graph: &g,
        remove_frac: frac,
        trials: opts.trials,
        seed: opts.seed,
        runtime,
    };
    let baseline = exp.run_row(&opts.base_config())?;
    let mut rows = Vec::new();
    // CoreWalk tables include the no-propagation CoreWalk row first
    // (paper's Table 3).
    if embedder == Embedder::CoreWalk {
        let mut cw = opts.base_config();
        cw.embedder = Embedder::CoreWalk;
        rows.push(exp.run_row(&cw)?);
    }
    for &k0 in cores {
        let mut cfg = opts.base_config();
        cfg.embedder = embedder.clone();
        cfg.k0 = Some(k0);
        rows.push(exp.run_row(&cfg)?);
    }
    let t = render_table(title, &baseline, &rows);
    let text = t.render();
    write_out(opts, name, &text, Some(&t.to_csv()))?;
    Ok(text)
}

/// Tables 2/7 and 8: Facebook sweep with BOTH embedders (Dw core rows,
/// then CoreWalk + Cw core rows), like the appendix tables.
fn bench_facebook_table(
    name: &str,
    frac: f64,
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    let pct = (frac * 100.0) as u32;
    let title = format!(
        "Table {}: Link prediction on Facebook-like graph, {pct}% removed (K-core(Dw) + K-core(Cw))",
        if frac < 0.2 { "2/7" } else { "8" }
    );
    let cores: &[u32] = &[9, 17, 25, 33, 41, 49, 57, 65, 73, 81, 89, 97];
    let g = graph_by_name("facebook", opts.seed)?;
    let exp = Experiment {
        graph: &g,
        remove_frac: frac,
        trials: opts.trials,
        seed: opts.seed,
        runtime,
    };
    let baseline = exp.run_row(&opts.base_config())?;
    let mut rows = Vec::new();
    for &k0 in cores {
        let mut cfg = opts.base_config();
        cfg.k0 = Some(k0);
        rows.push(exp.run_row(&cfg)?);
    }
    let mut cw = opts.base_config();
    cw.embedder = Embedder::CoreWalk;
    rows.push(exp.run_row(&cw)?);
    for &k0 in cores {
        let mut cfg = opts.base_config();
        cfg.embedder = Embedder::CoreWalk;
        cfg.k0 = Some(k0);
        rows.push(exp.run_row(&cfg)?);
    }
    let t = render_table(&title, &baseline, &rows);
    let text = t.render();
    write_out(opts, name, &text, Some(&t.to_csv()))?;
    Ok(text)
}

/// Fig 1: number of walks per root core index (n = 15).
fn bench_fig1(opts: &BenchOpts) -> Result<String> {
    let g = graph_by_name("facebook", opts.seed)?;
    let d = core_decomposition(&g);
    let pts = corewalk::walks_per_core(&d, opts.walks_per_node.max(15));
    let series = vec![Series::new(
        "walks per node",
        'o',
        pts.iter().map(|&(k, n)| (k as f64, n as f64)).collect(),
    )];
    let mut text = ascii_plot(
        &format!(
            "Fig 1: walks generated vs root core index (n = {}, degeneracy = {})",
            opts.walks_per_node.max(15),
            d.degeneracy
        ),
        "core index",
        "walks",
        &series,
        70,
        16,
    );
    let reduction = corewalk::walk_reduction(&d, opts.walks_per_node.max(15));
    text.push_str(&format!(
        "total walk reduction vs uniform schedule: {:.1}%\n",
        reduction * 100.0
    ));
    write_out(opts, "fig1", &text, Some(&series_csv(&series)))?;
    Ok(text)
}

/// Figs 2/3: F1 and total time as functions of the initial core index,
/// for both embedders.
fn bench_fig23(
    name: &str,
    frac: f64,
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    let cores: &[u32] = &[9, 25, 41, 57, 73, 97];
    let g = graph_by_name("facebook", opts.seed)?;
    let exp = Experiment {
        graph: &g,
        remove_frac: frac,
        trials: opts.trials,
        seed: opts.seed,
        runtime,
    };
    let mut f1_series = Vec::new();
    let mut time_series = Vec::new();
    for (embedder, marker) in [(Embedder::DeepWalk, 'o'), (Embedder::CoreWalk, 'x')] {
        let mut f1_pts = Vec::new();
        let mut t_pts = Vec::new();
        for &k0 in cores {
            let mut cfg = opts.base_config();
            cfg.embedder = embedder.clone();
            cfg.k0 = Some(k0);
            let row = exp.run_row(&cfg)?;
            f1_pts.push((k0 as f64, row.f1_pct()));
            t_pts.push((k0 as f64, row.total_secs.mean()));
        }
        let label = embedder.name();
        f1_series.push(Series::new(&format!("f1:{label}"), marker, f1_pts));
        time_series.push(Series::new(&format!("time:{label}"), marker, t_pts));
    }
    let pct = (frac * 100.0) as u32;
    let mut text = ascii_plot(
        &format!("Fig {name}: F1 vs initial core index ({pct}% removed)"),
        "k0",
        "F1 (%)",
        &f1_series,
        70,
        14,
    );
    text.push_str(&ascii_plot(
        &format!("Fig {name}: total execution time vs initial core index ({pct}% removed)"),
        "k0",
        "seconds",
        &time_series,
        70,
        14,
    ));
    let mut all = f1_series;
    all.extend(time_series);
    write_out(opts, name, &text, Some(&series_csv(&all)))?;
    Ok(text)
}

/// Fig 4: (top) nodes in the initial k-core; (bottom) per-phase time
/// breakdown vs k0.
fn bench_fig4(opts: &BenchOpts, runtime: Option<(&Runtime, &Manifest)>) -> Result<String> {
    let g = graph_by_name("facebook", opts.seed)?;
    let d = core_decomposition(&g);
    let sizes = subcore::core_sizes(&d);
    let size_series = vec![Series::new(
        "k-core size",
        '#',
        sizes.iter().map(|&(k, n)| (k as f64, n as f64)).collect(),
    )];
    let mut text = ascii_plot(
        "Fig 4 (top): nodes in the initial k-core to embed",
        "k",
        "nodes",
        &size_series,
        70,
        14,
    );

    let cores: &[u32] = &[9, 25, 41, 57, 73, 97];
    let exp = Experiment {
        graph: &g,
        remove_frac: 0.10,
        trials: opts.trials.min(3),
        seed: opts.seed,
        runtime,
    };
    let mut t = Table::new(
        "Fig 4 (bottom): execution-time breakdown vs initial core index (10% removed, Dw)",
        &["k0", "core nodes", "decomp (s)", "prop (s)", "embed (s)", "total (s)"],
    );
    let mut breakdown_series: Vec<Series> = Vec::new();
    let mut decomp_pts = Vec::new();
    let mut prop_pts = Vec::new();
    let mut embed_pts = Vec::new();
    for &k0 in cores {
        let mut cfg = opts.base_config();
        cfg.k0 = Some(k0);
        let row = exp.run_row(&cfg)?;
        t.add_row(vec![
            k0.to_string(),
            row.core_size.to_string(),
            format!("{:.2}", row.decomp_secs.mean()),
            format!("{:.2}", row.prop_secs.mean()),
            format!("{:.2}", row.embed_secs.mean()),
            format!("{:.2}", row.total_secs.mean()),
        ]);
        decomp_pts.push((k0 as f64, row.decomp_secs.mean()));
        prop_pts.push((k0 as f64, row.prop_secs.mean()));
        embed_pts.push((k0 as f64, row.embed_secs.mean()));
    }
    breakdown_series.push(Series::new("decomp", 'd', decomp_pts));
    breakdown_series.push(Series::new("prop", 'p', prop_pts));
    breakdown_series.push(Series::new("embed", 'e', embed_pts));
    text.push_str(&t.render());
    let mut all = size_series;
    all.extend(breakdown_series);
    write_out(opts, "fig4", &text, Some(&series_csv(&all)))?;
    Ok(text)
}

/// Figs 5/6: PCA projection of the final embeddings when the initially
/// embedded core is connected (Fig 5) vs disconnected (Fig 6).
fn bench_fig56(
    name: &str,
    connected: bool,
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    let g = graph_by_name("facebook", opts.seed)?;
    let mut rng = crate::util::rng::Rng::new(opts.seed);
    let split = crate::eval::split_edges(&g, 0.10, &mut rng);
    // Pick k0 on the *train* graph (removal shifts core numbers):
    // largest connected core for Fig 5; the largest DISCONNECTED core for
    // Fig 6 (the calibrated facebook graph has a two-blob band).
    let d_train = core_decomposition(&split.train_graph);
    let k0 = if connected {
        subcore::max_connected_core(&split.train_graph, &d_train)
    } else {
        (2..=d_train.degeneracy)
            .rev()
            .find(|&k| !subcore::k_core_connected(&split.train_graph, &d_train, k))
            .unwrap_or(d_train.degeneracy)
    };
    let mut cfg = opts.base_config();
    cfg.k0 = Some(k0);
    let out = run_pipeline(&split.train_graph, &cfg, runtime)?;

    let emb = &out.embedding;
    let pca = Pca::fit(emb.data(), emb.n(), emb.dim(), 2);
    let proj = pca.transform(emb.data(), emb.n(), emb.dim());
    let core_flag: Vec<bool> = (0..g.n_nodes())
        .map(|v| d_train.core[v] >= k0)
        .collect();
    let core_pts: Vec<(f64, f64)> = proj
        .iter()
        .zip(&core_flag)
        .filter(|(_, &c)| c)
        .map(|(p, _)| (p[0], p[1]))
        .collect();
    let prop_pts: Vec<(f64, f64)> = proj
        .iter()
        .zip(&core_flag)
        .filter(|(_, &c)| !c)
        .map(|(p, _)| (p[0], p[1]))
        .collect();
    let series = vec![
        Series::new("k0-core (trained)", 'o', core_pts),
        Series::new("propagated", '.', prop_pts),
    ];
    let is_conn = subcore::k_core_connected(&split.train_graph, &d_train, k0);
    let mut text = ascii_plot(
        &format!(
            "Fig {name}: PCA of embeddings, initial {k0}-core ({} — {})",
            if is_conn { "connected" } else { "NOT connected" },
            if connected {
                "Fig 5 scenario"
            } else {
                "Fig 6 scenario"
            }
        ),
        "PC1",
        "PC2",
        &series,
        78,
        22,
    );
    text.push_str(&format!(
        "explained variance: PC1 {:.3}, PC2 {:.3} (ratio {:.1})\n",
        pca.explained[0],
        pca.explained[1],
        pca.explained[0] / pca.explained[1].max(1e-12)
    ));
    write_out(opts, name, &text, Some(&series_csv(&series)))?;
    Ok(text)
}

/// Ablation: edge-feature operator (the paper's concat vs node2vec's
/// binary operators) on a fixed CoreWalk embedding.
fn bench_ablate_op(opts: &BenchOpts, runtime: Option<(&Runtime, &Manifest)>) -> Result<String> {
    use crate::eval::linkpred::evaluate_link_prediction_with;
    use crate::eval::EdgeOp;
    let g = graph_by_name("facebook", opts.seed)?;
    let mut t = Table::new(
        "Ablation: edge-feature operator, CoreWalk embedding, Facebook-like 10% removed",
        &["Operator", "F1-Score (%)", "AUC"],
    );
    let mut f1s: Vec<crate::util::stats::MeanStd> =
        vec![crate::util::stats::MeanStd::new(); EdgeOp::ALL.len()];
    let mut aucs = f1s.clone();
    for trial in 0..opts.trials {
        let mut rng = crate::util::rng::Rng::new(opts.seed ^ (0xAB1 + trial as u64));
        let split = crate::eval::split_edges(&g, 0.10, &mut rng);
        let mut cfg = opts.base_config();
        cfg.embedder = Embedder::CoreWalk;
        cfg.seed = opts.seed ^ ((trial as u64) << 8);
        let out = run_pipeline(&split.train_graph, &cfg, runtime)?;
        for (i, op) in EdgeOp::ALL.iter().enumerate() {
            let r = evaluate_link_prediction_with(
                &g,
                &split.removed,
                &out.embedding,
                *op,
                &mut crate::util::rng::Rng::new(99 ^ trial as u64),
            );
            f1s[i].push(r.f1);
            aucs[i].push(r.auc);
        }
    }
    for (i, op) in EdgeOp::ALL.iter().enumerate() {
        t.add_row(vec![
            op.name().to_string(),
            crate::util::table::mean_std_cell(f1s[i].mean() * 100.0, f1s[i].std() * 100.0, 2),
            format!("{:.3}", aucs[i].mean()),
        ]);
    }
    let text = t.render();
    write_out(opts, "ablate-op", &text, Some(&t.to_csv()))?;
    Ok(text)
}

/// Ablation: bridge walks on a disconnected k0-core (paper §4's proposed
/// fix) — does bridging recover F1 / normalize the PCA variance ratio?
fn bench_ablate_bridge(
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    let g = graph_by_name("facebook", opts.seed)?;
    let mut rng = crate::util::rng::Rng::new(opts.seed);
    let split = crate::eval::split_edges(&g, 0.10, &mut rng);
    let d_train = core_decomposition(&split.train_graph);
    let k0 = (2..=d_train.degeneracy)
        .rev()
        .find(|&k| !subcore::k_core_connected(&split.train_graph, &d_train, k))
        .unwrap_or(d_train.degeneracy);
    let mut t = Table::new(
        &format!("Ablation: bridge walks on the disconnected {k0}-core (Facebook-like, 10% removed)"),
        &["Bridges", "F1-Score (%)", "AUC", "PC1/PC2 variance ratio"],
    );
    for bridges in [0usize, 50, 200] {
        let mut f1 = crate::util::stats::MeanStd::new();
        let mut auc = crate::util::stats::MeanStd::new();
        let mut ratio = crate::util::stats::MeanStd::new();
        for trial in 0..opts.trials {
            let mut cfg = opts.base_config();
            cfg.k0 = Some(k0);
            cfg.bridge_walks = bridges;
            cfg.seed = opts.seed ^ ((trial as u64) << 24);
            let out = run_pipeline(&split.train_graph, &cfg, runtime)?;
            let r = crate::eval::evaluate_link_prediction(
                &g,
                &split.removed,
                &out.embedding,
                &mut crate::util::rng::Rng::new(7 ^ trial as u64),
            );
            f1.push(r.f1);
            auc.push(r.auc);
            let emb = &out.embedding;
            let pca = Pca::fit(emb.data(), emb.n(), emb.dim(), 2);
            ratio.push(pca.explained[0] / pca.explained[1].max(1e-12));
        }
        t.add_row(vec![
            bridges.to_string(),
            crate::util::table::mean_std_cell(f1.mean() * 100.0, f1.std() * 100.0, 2),
            format!("{:.3}", auc.mean()),
            format!("{:.1}", ratio.mean()),
        ]);
    }
    let text = t.render();
    write_out(opts, "ablate-bridge", &text, Some(&t.to_csv()))?;
    Ok(text)
}

/// Ablation: the paper's n (max walks per node) — quality/time trade of
/// the CoreWalk schedule's single knob.
fn bench_ablate_walks(
    opts: &BenchOpts,
    runtime: Option<(&Runtime, &Manifest)>,
) -> Result<String> {
    let g = graph_by_name("facebook", opts.seed)?;
    let exp = Experiment {
        graph: &g,
        remove_frac: 0.10,
        trials: opts.trials,
        seed: opts.seed,
        runtime,
    };
    let mut t = Table::new(
        "Ablation: walks-per-node n (CoreWalk, Facebook-like, 10% removed)",
        &["n", "F1-Score (%)", "Total (s)", "Pairs"],
    );
    for n in [3u32, 7, 15, 30] {
        let mut cfg = opts.base_config();
        cfg.embedder = Embedder::CoreWalk;
        cfg.walks_per_node = n;
        let row = exp.run_row(&cfg)?;
        t.add_row(vec![
            n.to_string(),
            crate::util::table::mean_std_cell(row.f1_pct(), row.f1.std() * 100.0, 2),
            format!("{:.2}", row.total_secs.mean()),
            row.n_pairs.to_string(),
        ]);
    }
    let text = t.render();
    write_out(opts, "ablate-walks", &text, Some(&t.to_csv()))?;
    Ok(text)
}

/// §3.1.1: nodes per k-shell for all three graphs.
fn bench_coredist(opts: &BenchOpts) -> Result<String> {
    let mut text = String::new();
    let mut all_series = Vec::new();
    for (name, marker) in [("cora", 'c'), ("facebook", 'f'), ("github", 'g')] {
        let g = graph_by_name(name, opts.seed)?;
        let d = core_decomposition(&g);
        let shells = subcore::shell_histogram(&d);
        let pts: Vec<(f64, f64)> = shells
            .iter()
            .map(|&(k, n)| (k as f64, (n as f64).max(1.0).log10()))
            .collect();
        text.push_str(&ascii_plot(
            &format!(
                "§3.1.1 {name}-like: nodes per shell (log10 count), degeneracy {}",
                d.degeneracy
            ),
            "core index",
            "log10(nodes)",
            &[Series::new(name, marker, pts.clone())],
            70,
            12,
        ));
        all_series.push(Series::new(name, marker, pts));
    }
    write_out(opts, "coredist", &text, Some(&series_csv(&all_series)))?;
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_opts() -> BenchOpts {
        let mut o = BenchOpts::quick();
        o.trials = 1;
        o.walks_per_node = 2;
        o.out_dir = std::env::temp_dir().join(format!("kcore_bench_{}", std::process::id()));
        o
    }

    #[test]
    fn unknown_bench_is_error() {
        assert!(run_bench("nope", &tmp_opts(), None).is_err());
    }

    #[test]
    fn fig1_and_coredist_run() {
        let opts = tmp_opts();
        let out = run_bench("fig1", &opts, None).unwrap();
        assert!(out.contains("walk reduction"));
        assert!(opts.out_dir.join("fig1.csv").exists());
        let out = run_bench("coredist", &opts, None).unwrap();
        assert!(out.contains("degeneracy"));
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn table1_quick_runs_native() {
        let opts = tmp_opts();
        let out = run_bench("table1", &opts, None).unwrap();
        assert!(out.contains("DeepWalk"));
        assert!(out.contains("-core (Dw)"));
        assert!(opts.out_dir.join("table1.csv").exists());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
