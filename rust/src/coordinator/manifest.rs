//! Durable job manifest: the pipeline's crash-safety ledger.
//!
//! `embed --job-dir <dir>` keeps a single manifest file in the job
//! directory recording the semantic config hash
//! ([`super::PipelineConfig::config_hash`]), the input-graph
//! fingerprint ([`crate::graph::Graph::fingerprint`]) and, per completed phase, a
//! completion record: output files with sizes + checksums, sealed
//! corpus shard metadata, and scalar facts the resume path needs. The
//! manifest is rewritten through [`fsio::write_atomic_durable`] after
//! each phase, so at every instant the file on disk is a complete,
//! checksummed description of exactly the phases whose outputs are
//! durable.
//!
//! On-disk format — a self-checking header line, then a JSON body:
//!
//! ```text
//! KCEMANIFEST1 <fnv1a64-of-body, 16 hex digits>\n
//! { "config_hash": "...", "graph_hash": "...", "phases": { ... } }
//! ```
//!
//! The checksum-in-header shape means loading never depends on
//! re-serializing the body byte-identically; the body is hashed as raw
//! bytes. Any defect — truncation, a flipped bit, a different config
//! hash, a different input graph — surfaces as a typed
//! [`ManifestError`], and the pipeline falls back to a fresh run
//! rather than trusting stale phase outputs.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::fsio;
use crate::util::json::Json;
use crate::walks::SealedShardMeta;

/// Manifest file name inside a job directory.
pub const MANIFEST_FILE: &str = "MANIFEST";
const HEADER_TAG: &str = "KCEMANIFEST1";

/// Path of the manifest inside `job_dir`.
pub fn manifest_path(job_dir: &Path) -> PathBuf {
    job_dir.join(MANIFEST_FILE)
}

/// Why a manifest could not be used for resume. Every variant means
/// "start fresh", but callers log which gate tripped.
#[derive(Debug, PartialEq, Eq)]
pub enum ManifestError {
    /// No manifest file — a brand-new job dir.
    Missing,
    /// File too short to even hold the header line.
    Truncated,
    /// Header tag is not ours (or the header line is malformed).
    BadMagic,
    /// Body bytes do not hash to the header checksum.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// Body is not the JSON shape we write.
    Parse(String),
    /// Manifest belongs to a different semantic configuration.
    ConfigHashMismatch { manifest: u64, current: u64 },
    /// Manifest was written for a different input graph — same knobs,
    /// different edges (the dynamic-graph rerun case): its phase
    /// outputs must never be donated to this run.
    GraphHashMismatch { manifest: u64, current: u64 },
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Missing => write!(f, "no manifest"),
            ManifestError::Truncated => write!(f, "manifest truncated"),
            ManifestError::BadMagic => write!(f, "not a job manifest (bad header)"),
            ManifestError::ChecksumMismatch { stored, computed } => write!(
                f,
                "manifest checksum mismatch: header {stored:016x}, body {computed:016x}"
            ),
            ManifestError::Parse(msg) => write!(f, "manifest body unreadable: {msg}"),
            ManifestError::ConfigHashMismatch { manifest, current } => write!(
                f,
                "manifest config hash {manifest:016x} != current {current:016x}"
            ),
            ManifestError::GraphHashMismatch { manifest, current } => write!(
                f,
                "manifest graph hash {manifest:016x} != current {current:016x} \
                 (input graph changed)"
            ),
        }
    }
}

impl std::error::Error for ManifestError {}

/// One output file of a completed phase. `path` is relative to the job
/// dir unless absolute (the export artifact lives wherever
/// `--export-store` pointed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactRecord {
    pub path: String,
    pub bytes: u64,
    pub checksum: u64,
}

impl ArtifactRecord {
    /// Record `path` (relative to `job_dir` unless absolute) as it
    /// exists on disk right now.
    pub fn capture(job_dir: &Path, path: &str) -> Result<ArtifactRecord> {
        let full = resolve(job_dir, path);
        let bytes = std::fs::metadata(&full)
            .with_context(|| format!("stat {}", full.display()))?
            .len();
        let checksum = fsio::file_checksum(&full)
            .with_context(|| format!("checksumming {}", full.display()))?;
        Ok(ArtifactRecord {
            path: path.to_string(),
            bytes,
            checksum,
        })
    }

    /// Does the file still exist with the recorded size and checksum?
    pub fn verify(&self, job_dir: &Path) -> bool {
        let full = resolve(job_dir, &self.path);
        match std::fs::metadata(&full) {
            Ok(m) if m.len() == self.bytes => {
                matches!(fsio::file_checksum(&full), Ok(c) if c == self.checksum)
            }
            _ => false,
        }
    }
}

/// Resolve a manifest-recorded path against the job dir.
pub fn resolve(job_dir: &Path, path: &str) -> PathBuf {
    let p = Path::new(path);
    if p.is_absolute() {
        p.to_path_buf()
    } else {
        job_dir.join(p)
    }
}

/// Completion record of one pipeline phase.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseRecord {
    /// Output files with integrity metadata.
    pub artifacts: Vec<ArtifactRecord>,
    /// Sealed corpus shards (walks phase only).
    pub shards: Vec<SealedShardMeta>,
    /// Phase-specific scalar facts (counts, k0, ...) for the resume
    /// path and for humans reading the manifest.
    pub info: Vec<(String, f64)>,
}

impl PhaseRecord {
    pub fn info(&self, key: &str) -> Option<f64> {
        self.info.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }
}

/// The manifest: config + input-graph binding, plus per-phase
/// completion records.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub config_hash: u64,
    /// Fingerprint of the input graph the recorded phases were computed
    /// from ([`crate::graph::Graph::fingerprint`]).
    pub graph_hash: u64,
    pub seed: u64,
    phases: BTreeMap<String, PhaseRecord>,
}

impl Manifest {
    pub fn new(config_hash: u64, graph_hash: u64, seed: u64) -> Manifest {
        Manifest {
            config_hash,
            graph_hash,
            seed,
            phases: BTreeMap::new(),
        }
    }

    /// Completion record of `phase`, if that phase finished durably.
    pub fn phase(&self, phase: &str) -> Option<&PhaseRecord> {
        self.phases.get(phase)
    }

    /// Number of durably completed phases.
    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    /// Record `phase` as complete. Call [`Self::store`] afterwards —
    /// a phase is only *durably* complete once the manifest rewrite
    /// lands.
    pub fn record_phase(&mut self, phase: &str, record: PhaseRecord) {
        self.phases.insert(phase.to_string(), record);
    }

    /// Drop a phase record (and, implicitly, everything recorded for
    /// phases that depend on it being re-run).
    pub fn clear_phase(&mut self, phase: &str) {
        self.phases.remove(phase);
    }

    fn to_json(&self) -> Json {
        let phases: BTreeMap<String, Json> = self
            .phases
            .iter()
            .map(|(name, rec)| {
                let artifacts = rec
                    .artifacts
                    .iter()
                    .map(|a| {
                        Json::object(vec![
                            ("path", Json::str(&a.path)),
                            ("bytes", Json::num(a.bytes as f64)),
                            ("checksum", Json::str(&format!("{:016x}", a.checksum))),
                        ])
                    })
                    .collect();
                let shards = rec.shards.iter().map(shard_to_json).collect();
                let info: BTreeMap<String, Json> = rec
                    .info
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::num(*v)))
                    .collect();
                (
                    name.clone(),
                    Json::object(vec![
                        ("artifacts", Json::Array(artifacts)),
                        ("shards", Json::Array(shards)),
                        ("info", Json::Object(info)),
                    ]),
                )
            })
            .collect();
        Json::object(vec![
            ("config_hash", Json::str(&format!("{:016x}", self.config_hash))),
            ("graph_hash", Json::str(&format!("{:016x}", self.graph_hash))),
            ("seed", Json::num(self.seed as f64)),
            ("phases", Json::Object(phases)),
        ])
    }

    fn from_json(j: &Json) -> Result<Manifest, ManifestError> {
        let bad = |msg: &str| ManifestError::Parse(msg.to_string());
        let config_hash = j
            .get("config_hash")
            .and_then(Json::as_str)
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| bad("config_hash"))?;
        let graph_hash = j
            .get("graph_hash")
            .and_then(Json::as_str)
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| bad("graph_hash"))?;
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let mut phases = BTreeMap::new();
        if let Some(Json::Object(m)) = j.get("phases") {
            for (name, rec) in m {
                let mut out = PhaseRecord::default();
                for a in rec.get("artifacts").and_then(Json::as_array).unwrap_or(&[]) {
                    out.artifacts.push(ArtifactRecord {
                        path: a
                            .get("path")
                            .and_then(Json::as_str)
                            .ok_or_else(|| bad("artifact path"))?
                            .to_string(),
                        bytes: a
                            .get("bytes")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| bad("artifact bytes"))?
                            as u64,
                        checksum: a
                            .get("checksum")
                            .and_then(Json::as_str)
                            .and_then(|s| u64::from_str_radix(s, 16).ok())
                            .ok_or_else(|| bad("artifact checksum"))?,
                    });
                }
                for s in rec.get("shards").and_then(Json::as_array).unwrap_or(&[]) {
                    out.shards.push(shard_from_json(s).ok_or_else(|| bad("shard"))?);
                }
                if let Some(Json::Object(info)) = rec.get("info") {
                    for (k, v) in info {
                        out.info.push((k.clone(), v.as_f64().ok_or_else(|| bad("info value"))?));
                    }
                }
                phases.insert(name.clone(), out);
            }
        }
        Ok(Manifest {
            config_hash,
            graph_hash,
            seed,
            phases,
        })
    }

    /// Serialize and write durably (tmp → fsync → rename → dir fsync).
    pub fn store(&self, path: &Path) -> Result<()> {
        let body = self.to_json().to_string();
        let checksum = fsio::fnv1a64(&[body.as_bytes()]);
        let text = format!("{HEADER_TAG} {checksum:016x}\n{body}\n");
        fsio::write_atomic_durable(path, text.as_bytes())
            .with_context(|| format!("writing job manifest {}", path.display()))
    }

    /// Load and fully validate a manifest: header tag, body checksum,
    /// JSON shape, the semantic config hash, and the input-graph
    /// fingerprint. Every failure is a typed [`ManifestError`] — the
    /// caller logs it and starts fresh.
    pub fn load(
        path: &Path,
        current_config_hash: u64,
        current_graph_hash: u64,
    ) -> Result<Manifest, ManifestError> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(ManifestError::Missing)
            }
            Err(e) => return Err(ManifestError::Parse(e.to_string())),
        };
        let Some((header, body)) = text.split_once('\n') else {
            return Err(ManifestError::Truncated);
        };
        let Some((tag, hex)) = header.split_once(' ') else {
            return Err(ManifestError::BadMagic);
        };
        if tag != HEADER_TAG {
            return Err(ManifestError::BadMagic);
        }
        let stored = u64::from_str_radix(hex.trim(), 16).map_err(|_| ManifestError::BadMagic)?;
        let body = body.strip_suffix('\n').unwrap_or(body);
        let computed = fsio::fnv1a64(&[body.as_bytes()]);
        if stored != computed {
            return Err(ManifestError::ChecksumMismatch { stored, computed });
        }
        let json = Json::parse(body).map_err(|e| ManifestError::Parse(e.to_string()))?;
        let manifest = Manifest::from_json(&json)?;
        if manifest.config_hash != current_config_hash {
            return Err(ManifestError::ConfigHashMismatch {
                manifest: manifest.config_hash,
                current: current_config_hash,
            });
        }
        if manifest.graph_hash != current_graph_hash {
            return Err(ManifestError::GraphHashMismatch {
                manifest: manifest.graph_hash,
                current: current_graph_hash,
            });
        }
        Ok(manifest)
    }
}

fn shard_to_json(s: &SealedShardMeta) -> Json {
    Json::object(vec![
        ("n_walks", Json::num(s.n_walks as f64)),
        ("n_tokens", Json::num(s.n_tokens as f64)),
        (
            "len_hist",
            Json::Array(s.len_hist.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        ("bytes", Json::num(s.bytes as f64)),
        ("checksum", Json::str(&format!("{:016x}", s.checksum))),
    ])
}

fn shard_from_json(j: &Json) -> Option<SealedShardMeta> {
    Some(SealedShardMeta {
        n_walks: j.get("n_walks")?.as_f64()? as u64,
        n_tokens: j.get("n_tokens")?.as_f64()? as u64,
        len_hist: j
            .get("len_hist")?
            .as_array()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as u64))
            .collect::<Option<Vec<u64>>>()?,
        bytes: j.get("bytes")?.as_f64()? as u64,
        checksum: u64::from_str_radix(j.get("checksum")?.as_str()?, 16).ok()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("kcore_manifest_{}_{}", name, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> Manifest {
        let mut m = Manifest::new(0xDEAD_BEEF_1234_5678, 0xFACE_0FF0_5511_AA22, 7);
        m.record_phase(
            "walks",
            PhaseRecord {
                artifacts: vec![],
                shards: vec![SealedShardMeta {
                    n_walks: 10,
                    n_tokens: 100,
                    len_hist: vec![0, 0, 0, 4, 6],
                    bytes: 440,
                    checksum: 0xFFFF_0000_ABCD_0001,
                }],
                info: vec![("n_walks".into(), 10.0)],
            },
        );
        m.record_phase(
            "train",
            PhaseRecord {
                artifacts: vec![ArtifactRecord {
                    path: "train.kce".into(),
                    bytes: 4096,
                    checksum: 0x0123_4567_89AB_CDEF,
                }],
                shards: vec![],
                info: vec![("n_pairs".into(), 5000.0)],
            },
        );
        m
    }

    #[test]
    fn round_trip_preserves_everything() {
        let d = tmp_dir("roundtrip");
        let p = manifest_path(&d);
        let m = sample();
        m.store(&p).unwrap();
        let back = Manifest::load(&p, m.config_hash, m.graph_hash).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.phase("train").unwrap().info("n_pairs"), Some(5000.0));
        assert_eq!(back.phase("walks").unwrap().shards[0].checksum, 0xFFFF_0000_ABCD_0001);
        assert!(back.phase("export").is_none());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_truncated_tampered_and_mismatched_are_typed() {
        let d = tmp_dir("tamper");
        let p = manifest_path(&d);
        let m = sample();

        assert_eq!(
            Manifest::load(&p, m.config_hash, m.graph_hash),
            Err(ManifestError::Missing)
        );

        // Truncated: cut the file mid-body.
        m.store(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, &text[..text.len() / 2]).unwrap();
        assert!(matches!(
            Manifest::load(&p, m.config_hash, m.graph_hash),
            Err(ManifestError::ChecksumMismatch { .. })
        ));

        // Header-only truncation (no newline at all).
        std::fs::write(&p, "KCEMANIFEST1 0123").unwrap();
        assert_eq!(
            Manifest::load(&p, m.config_hash, m.graph_hash),
            Err(ManifestError::Truncated)
        );

        // Bit flip inside the body.
        m.store(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let off = bytes.len() - 10;
        bytes[off] ^= 0x20;
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            Manifest::load(&p, m.config_hash, m.graph_hash),
            Err(ManifestError::ChecksumMismatch { .. })
        ));

        // Wrong magic.
        std::fs::write(&p, "NOTAMANIFEST 0123456789abcdef\n{}").unwrap();
        assert_eq!(
            Manifest::load(&p, m.config_hash, m.graph_hash),
            Err(ManifestError::BadMagic)
        );

        // Intact file, different semantic config.
        m.store(&p).unwrap();
        assert!(matches!(
            Manifest::load(&p, m.config_hash ^ 1, m.graph_hash),
            Err(ManifestError::ConfigHashMismatch { .. })
        ));

        // Intact file, same config, different input graph: the
        // dynamic-graph rerun case must refuse to donate phase outputs.
        assert_eq!(
            Manifest::load(&p, m.config_hash, m.graph_hash ^ 1),
            Err(ManifestError::GraphHashMismatch {
                manifest: m.graph_hash,
                current: m.graph_hash ^ 1,
            })
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn artifact_capture_and_verify_detect_drift() {
        let d = tmp_dir("artifacts");
        std::fs::write(d.join("out.bin"), b"payload-bytes").unwrap();
        let rec = ArtifactRecord::capture(&d, "out.bin").unwrap();
        assert!(rec.verify(&d));
        // Same length, different bytes: checksum catches it.
        std::fs::write(d.join("out.bin"), b"payload-BYTES").unwrap();
        assert!(!rec.verify(&d));
        // Gone entirely.
        std::fs::remove_file(d.join("out.bin")).unwrap();
        assert!(!rec.verify(&d));
        let _ = std::fs::remove_dir_all(&d);
    }
}
