//! Render experiment rows as the paper's tables (model, F1 ± std, perf
//! drop, per-phase breakdown, total ± std, speedup), plus the serving
//! tier's per-batch latency-percentile table.

use crate::coordinator::experiment::RowResult;
use crate::serve::query::BatchReport;
use crate::util::table::{mean_std_cell, perf_drop_cell, speedup_cell, Table};

/// Full appendix-style table (Tables 5-10 layout; the main-text tables
/// are column subsets of this).
pub fn render_table(title: &str, baseline: &RowResult, rows: &[RowResult]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Model",
            "F1-Score (%)",
            "Perf. Drop",
            "Core decomp. (s)",
            "Propagation (s)",
            "Embedding (s)",
            "Total (s)",
            "Speedup",
        ],
    );
    t.add_row(row_cells(baseline, None));
    for r in rows {
        t.add_row(row_cells(r, Some(baseline)));
    }
    t
}

fn row_cells(r: &RowResult, baseline: Option<&RowResult>) -> Vec<String> {
    let f1_cell = mean_std_cell(r.f1.mean() * 100.0, r.f1.std() * 100.0, 2);
    let (drop, speedup) = match baseline {
        None => ("".to_string(), "".to_string()),
        Some(b) => (
            perf_drop_cell(b.f1.mean() * 100.0, r.f1.mean() * 100.0),
            speedup_cell(b.total_secs.mean(), r.total_secs.mean()),
        ),
    };
    vec![
        r.label.clone(),
        f1_cell,
        drop,
        format!("{:.2}", r.decomp_secs.mean()),
        format!("{:.2}", r.prop_secs.mean()),
        format!("{:.2}", r.embed_secs.mean()),
        mean_std_cell(r.total_secs.mean(), r.total_secs.std(), 2),
        speedup,
    ]
}

/// Serving telemetry table: one row per executed batch plus an `all`
/// summary row over every request (nearest-rank percentiles, µs).
pub fn render_latency_table(title: &str, reports: &[BatchReport]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Batch",
            "Requests",
            "p50 (us)",
            "p90 (us)",
            "p99 (us)",
            "max (us)",
            "Total (ms)",
        ],
    );
    for r in reports {
        t.add_row(vec![
            r.batch.to_string(),
            r.n_requests.to_string(),
            format!("{:.1}", r.p50_us),
            format!("{:.1}", r.p90_us),
            format!("{:.1}", r.p99_us),
            format!("{:.1}", r.max_us),
            format!("{:.2}", r.total_ms),
        ]);
    }
    if reports.len() > 1 {
        // Aggregate row: percentile-of-percentiles is not a percentile,
        // so summarize with worst-case values and total volume instead.
        let total_req: usize = reports.iter().map(|r| r.n_requests).sum();
        let worst = |f: fn(&BatchReport) -> f64| {
            reports.iter().map(f).fold(0f64, f64::max)
        };
        t.add_row(vec![
            "all (worst)".to_string(),
            total_req.to_string(),
            format!("{:.1}", worst(|r| r.p50_us)),
            format!("{:.1}", worst(|r| r.p90_us)),
            format!("{:.1}", worst(|r| r.p99_us)),
            format!("{:.1}", worst(|r| r.max_us)),
            format!("{:.2}", reports.iter().map(|r| r.total_ms).sum::<f64>()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::MeanStd;

    fn row(label: &str, f1: f64, total: f64) -> RowResult {
        RowResult {
            label: label.into(),
            f1: MeanStd::from_slice(&[f1, f1 + 0.01]),
            auc: MeanStd::from_slice(&[0.8]),
            total_secs: MeanStd::from_slice(&[total, total * 1.1]),
            decomp_secs: MeanStd::from_slice(&[0.1]),
            prop_secs: MeanStd::from_slice(&[0.2]),
            embed_secs: MeanStd::from_slice(&[total - 0.3]),
            core_size: 100,
            n_walks: 500,
            n_pairs: 10_000,
        }
    }

    #[test]
    fn table_shape_and_speedup() {
        let base = row("DeepWalk", 0.71, 10.0);
        let rows = vec![row("9-core (Dw)", 0.69, 5.0), row("25-core (Dw)", 0.67, 2.0)];
        let t = render_table("Table 2", &base, &rows);
        let s = t.render();
        assert!(s.contains("Table 2"));
        assert!(s.contains("DeepWalk"));
        assert!(s.contains("x2.1") || s.contains("x2.0"), "{s}");
        assert!(s.contains("x5.2") || s.contains("x5.3") || s.contains("x5.0"), "{s}");
        assert!(s.contains("-2.0") || s.contains("-1.9"), "{s}");
        let csv = t.to_csv();
        assert!(csv.lines().count() == 4);
    }

    #[test]
    fn latency_table_rows_and_summary() {
        let rep = |batch: usize, n: usize, p50: f64| BatchReport {
            batch,
            n_requests: n,
            p50_us: p50,
            p90_us: p50 * 2.0,
            p99_us: p50 * 3.0,
            max_us: p50 * 4.0,
            total_ms: 1.5,
        };
        let t = render_latency_table("Serve latency", &[rep(1, 64, 100.0), rep(2, 10, 250.0)]);
        assert_eq!(t.n_rows(), 3); // 2 batches + worst-case summary
        let s = t.render();
        assert!(s.contains("Serve latency"));
        assert!(s.contains("p99"));
        assert!(s.contains("74")); // 64 + 10 total requests
        assert!(s.contains("250.0")); // worst p50 carried into summary
        // Single batch: no summary row.
        let t1 = render_latency_table("one", &[rep(1, 5, 10.0)]);
        assert_eq!(t1.n_rows(), 1);
    }
}
