//! Render experiment rows as the paper's tables (model, F1 ± std, perf
//! drop, per-phase breakdown, total ± std, speedup).

use crate::coordinator::experiment::RowResult;
use crate::util::table::{mean_std_cell, perf_drop_cell, speedup_cell, Table};

/// Full appendix-style table (Tables 5-10 layout; the main-text tables
/// are column subsets of this).
pub fn render_table(title: &str, baseline: &RowResult, rows: &[RowResult]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Model",
            "F1-Score (%)",
            "Perf. Drop",
            "Core decomp. (s)",
            "Propagation (s)",
            "Embedding (s)",
            "Total (s)",
            "Speedup",
        ],
    );
    t.add_row(row_cells(baseline, None));
    for r in rows {
        t.add_row(row_cells(r, Some(baseline)));
    }
    t
}

fn row_cells(r: &RowResult, baseline: Option<&RowResult>) -> Vec<String> {
    let f1_cell = mean_std_cell(r.f1.mean() * 100.0, r.f1.std() * 100.0, 2);
    let (drop, speedup) = match baseline {
        None => ("".to_string(), "".to_string()),
        Some(b) => (
            perf_drop_cell(b.f1.mean() * 100.0, r.f1.mean() * 100.0),
            speedup_cell(b.total_secs.mean(), r.total_secs.mean()),
        ),
    };
    vec![
        r.label.clone(),
        f1_cell,
        drop,
        format!("{:.2}", r.decomp_secs.mean()),
        format!("{:.2}", r.prop_secs.mean()),
        format!("{:.2}", r.embed_secs.mean()),
        mean_std_cell(r.total_secs.mean(), r.total_secs.std(), 2),
        speedup,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::MeanStd;

    fn row(label: &str, f1: f64, total: f64) -> RowResult {
        RowResult {
            label: label.into(),
            f1: MeanStd::from_slice(&[f1, f1 + 0.01]),
            auc: MeanStd::from_slice(&[0.8]),
            total_secs: MeanStd::from_slice(&[total, total * 1.1]),
            decomp_secs: MeanStd::from_slice(&[0.1]),
            prop_secs: MeanStd::from_slice(&[0.2]),
            embed_secs: MeanStd::from_slice(&[total - 0.3]),
            core_size: 100,
            n_walks: 500,
            n_pairs: 10_000,
        }
    }

    #[test]
    fn table_shape_and_speedup() {
        let base = row("DeepWalk", 0.71, 10.0);
        let rows = vec![row("9-core (Dw)", 0.69, 5.0), row("25-core (Dw)", 0.67, 2.0)];
        let t = render_table("Table 2", &base, &rows);
        let s = t.render();
        assert!(s.contains("Table 2"));
        assert!(s.contains("DeepWalk"));
        assert!(s.contains("x2.1") || s.contains("x2.0"), "{s}");
        assert!(s.contains("x5.2") || s.contains("x5.3") || s.contains("x5.0"), "{s}");
        assert!(s.contains("-2.0") || s.contains("-1.9"), "{s}");
        let csv = t.to_csv();
        assert!(csv.lines().count() == 4);
    }
}
