//! `kcore-embed` — CLI for the k-core accelerated graph embedding system.
//!
//! Subcommands:
//!   generate   synthesize a dataset stand-in and save the edge list
//!   describe   structural summary + core decomposition of a graph
//!   embed      run the embedding pipeline, save embeddings as TSV
//!              (and optionally a binary serving artifact, --store)
//!   eval       full link-prediction experiment (trials, mean ± std)
//!   serve      answer batched neighbor/edge-score requests against an
//!              exported artifact (or, with --listen, run the
//!              persistent hot-swappable daemon on a unix socket)
//!   query      one-shot top-k / edge-score lookup against an artifact
//!              (or, with --connect, against a running daemon)
//!   bench      regenerate a paper table/figure (table1..table10, fig1..fig6,
//!              coredist, all)
//!
//! Graphs are either `--graph {cora,facebook,github}` (calibrated
//! stand-ins, see DESIGN.md §Substitutions) or `--edges <path>`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use kcore_embed::coordinator::bench::{run_bench, BenchOpts, BENCH_NAMES};
use kcore_embed::coordinator::experiment::Experiment;
use kcore_embed::coordinator::report::{render_latency_table, render_table};
use kcore_embed::coordinator::{run_pipeline_traced, Backend, Embedder, PipelineConfig};
use kcore_embed::cores::{core_decomposition, subcore};
use kcore_embed::eval::EdgeOp;
use kcore_embed::graph::{generators, io, metrics, Graph};
use kcore_embed::obs::trace::Tracer;
use kcore_embed::runtime::{default_artifacts_dir, Manifest, Runtime};
use kcore_embed::serve::{
    client_exchange, loadtest, notify_swap, run_server, AcceptModel, ClientMsg, EdgeScorer,
    EdgeScorerParams, EmbeddingStore, GenerationOpts, GenerationStore, Metric, QueryService,
    Request, Response, ServeAddr, ServeOpts, ServerOpts, TopKParams,
};
use kcore_embed::util::cli::Args;

const USAGE: &str = "\
kcore-embed — k-core accelerated graph representation learning

USAGE: kcore-embed <command> [options]

COMMANDS
  generate  --graph NAME [--seed N] --out PATH
  describe  (--graph NAME | --edges PATH) [--seed N]
  embed     (--graph NAME | --edges PATH) [--embedder deepwalk|corewalk|node2vec]
            [--p P] [--q Q] (node2vec bias knobs; must be positive finite)
            [--k0 K] [--backend pjrt|native] [--walks N] [--walk-length L]
            [--dim D] [--window W] [--epochs E] [--seed N]
            [--threads N] [--train-threads N]
            [--shards S] [--corpus-budget-mb M] [--spill-dir DIR]
            [--job-dir DIR [--ckpt-every N]]
            [--store ARTIFACT [--notify ADDR]] [--trace-out PATH] --out PATH
  eval      (--graph NAME | --edges PATH) [--remove FRAC] [--trials T]
            [--embedder ...] [--k0 K] [--cores K1,K2,...] [--backend ...]
            [--walks N] [--seed N]
  serve     --store ARTIFACT [--requests FILE] [--metric dot|cosine]
            [--quantized] [--batch N] [--top-k K] [--in-memory]
            [--threads N] [(--graph NAME | --edges PATH) [--op OP]]
            [--listen SOCKET | --listen-tcp HOST:PORT]  (daemon mode)
            [--accept-model threads|eventloop]
            [--max-conns N] [--read-timeout-ms MS] [--trace-out PATH]
            [--max-inflight N] [--faults SPEC] [--fault-seed N]
  query     --store ARTIFACT (--node V [--top-k K] | --edge U,V)
            [--metric dot|cosine] [--quantized] [--in-memory]
            [(--graph NAME | --edges PATH) [--op OP]]
  query     (--connect ADDR | --connect-tcp HOST:PORT)
            (--node V [--top-k K] | --edge U,V |
            --control swap --store ARTIFACT |
            --control stats|metrics|health|shutdown)
  loadgen   (--connect ADDR | --connect-tcp HOST:PORT)
            [--scenario baseline|fanout|fanin|poisson|idleherd|all]
            [--clients N] [--batches N] [--batch N] [--seed N] [--rate R]
            [--idle-conns N] [--json PATH --label NAME]
            (see `loadgen --help`)
  bench     --exp NAME [--trials T] [--walks N] [--backend pjrt|native]
            [--seed N] [--out-dir DIR] [--quick]

Corpus streaming (embed/eval): --shards S fixes the number of corpus
shards (0 = default 16; part of the determinism contract — corpora never
depend on --threads), --corpus-budget-mb M bounds resident corpus memory
by spilling shards to disk (0 = unbounded), and --spill-dir points spill
files at a dedicated scratch disk (default: OS temp dir). See DESIGN.md
§Corpus-streaming.

Native training (DESIGN.md §Training): --train-threads N sets the SGNS
hogwild worker count independently of --threads (0 = follow --threads);
1 selects the deterministic serial trainer, >1 runs racy hogwild on the
fused kernels. `make bench-train` records the kernel throughput.

Serving (DESIGN.md §Serving): `embed --store` exports a versioned binary
artifact (embedding + core numbers, checksummed); `serve`/`query` mmap
it back (--in-memory opts out) and scan it exactly or via the 8-bit
quantized fast path (--quantized, exact re-rank). `serve` reads request
lines ('nn NODE K' | 'edge U V') from --requests or stdin and prints a
per-batch latency-percentile table; edge scoring needs the serving
graph (--graph/--edges) to fit its logistic model at startup.

Daemon mode: `serve --listen SOCK` (unix socket) or `serve --listen-tcp
HOST:PORT` (TCP; port 0 picks an ephemeral port and prints it) keeps
serving and hot-swaps artifact generations without downtime —
re-exports over the watched path are picked up automatically, `embed
--notify ADDR` pushes a swap after export (ADDR is a socket path or
host:port), and `query --connect ADDR` / `--connect-tcp HOST:PORT`
sends queries or the swap/stats/metrics/health/shutdown control verbs
(stats, metrics and health answer one-line JSON). --max-conns caps live
connections (over-capacity clients get one parseable err line; 0 =
unlimited, default 256) and --read-timeout-ms closes connections idle
past the limit (0 disables, default 30000). --accept-model picks the
connection multiplexing model: `threads` (default) runs one handler
thread per connection, `eventloop` (Linux) multiplexes every connection
over one epoll loop plus a fixed worker pool, so N mostly-idle clients
cost N file descriptors instead of N threads. Both models speak the
same protocol and answer identical replies.

Crash safety (DESIGN.md §Robustness, \"Crash safety & resume\"): `embed
--job-dir DIR` makes the pipeline crash-only — each phase commits its
outputs to a checksummed KCEMANIFEST1 manifest under DIR with
write-tmp-fsync-rename discipline, so a killed run re-invoked with the
same --job-dir and config skips every completed phase and resumes
where it died. --ckpt-every N additionally checkpoints the serial
trainer every N epochs for mid-train resume (requires
--train-threads 1 for bit-exact replay). Stale temp/spill files from
dead runs are swept at startup (`pipeline: orphans_removed=N`).
`make crash` runs the kill-9 drill end to end. A restarted daemon
reopens the last-good generation recorded in the artifact's `.current`
lineage file; `health` reports recovered, lineage_generation,
start_time and uptime_secs.

Robustness (DESIGN.md §Robustness): the daemon degrades instead of
dying — a panicking connection handler is caught (one connection drops,
`serve.panics` counts it), a failed or corrupt swap keeps the last-good
generation serving (the `health` verb reports last_swap_result), and
--max-inflight N sheds batches past N concurrent executions with
parseable `err overloaded` lines (0 = unlimited, default). Failure
injection for chaos drills: --faults 'name=always|p|N[:VALUE],...'
arms named failpoints (see `make chaos`), --fault-seed N makes
probabilistic faults replayable; the KCORE_FAULTS / KCORE_FAULT_SEED
environment variables do the same for any subcommand.

Observability (DESIGN.md §Observability): --trace-out PATH (embed and
daemon-mode serve) writes span-trace JSONL — one span per pipeline
phase (load/decomposition/walks/train/propagation/export) or daemon
verb, plus /proc RSS/CPU series — and the daemon's `metrics` control
verb snapshots its full metrics registry (per-verb latency histograms,
connection counters) as one JSON line.

Load testing: `loadgen` drives a running daemon with deterministic
multi-client scenarios (including the idleherd mostly-idle herd) and
records latency histograms; `make bench-serve` snapshots
BENCH_serve.json for both accept models under the `threads` and
`eventloop` labels.

Run `make artifacts` once before using the pjrt backend.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.command.is_none() || args.has_flag("help") {
        print!("{USAGE}");
        return;
    }
    // Environment-driven failpoints (KCORE_FAULTS/KCORE_FAULT_SEED)
    // apply to every subcommand; `serve --faults` layers on top.
    if let Err(e) = kcore_embed::obs::faults::init_from_env() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
    let cmd = args.command.clone().unwrap();
    let result = match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "describe" => cmd_describe(&args),
        "embed" => cmd_embed(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "query" => cmd_query(&args),
        "loadgen" => loadtest::run_cli(&args),
        "bench" => cmd_bench(&args),
        other => Err(anyhow::anyhow!("unknown command {other:?}\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_graph(args: &Args) -> Result<Graph> {
    match maybe_load_graph(args)? {
        Some(g) => Ok(g),
        None => bail!("specify exactly one of --graph or --edges"),
    }
}

/// Like [`load_graph`], but absent `--graph`/`--edges` is not an error
/// (serve/query only need a graph when edge scoring is requested).
fn maybe_load_graph(args: &Args) -> Result<Option<Graph>> {
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;
    match (args.opt_str("graph"), args.opt_str("edges")) {
        (Some(name), None) => generators::by_name(&name, seed)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("unknown graph {name:?} (cora|facebook|github)")),
        (None, Some(path)) => io::load_edge_list(Path::new(&path), None).map(Some),
        (None, None) => Ok(None),
        _ => bail!("specify at most one of --graph or --edges"),
    }
}

fn parse_embedder(args: &Args) -> Result<Embedder> {
    Ok(match args.get_str("embedder", "deepwalk").as_str() {
        "deepwalk" => Embedder::DeepWalk,
        "corewalk" => Embedder::CoreWalk,
        "node2vec" => Embedder::Node2Vec {
            p: args.get_f64("p", 1.0).map_err(anyhow::Error::msg)?,
            q: args.get_f64("q", 1.0).map_err(anyhow::Error::msg)?,
        },
        x => bail!("unknown embedder {x:?}"),
    })
}

fn parse_backend(args: &Args, default: &str) -> Result<Backend> {
    Ok(match args.get_str("backend", default).as_str() {
        "pjrt" => Backend::Pjrt,
        "native" => Backend::Native,
        x => bail!("unknown backend {x:?}"),
    })
}

fn build_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = PipelineConfig {
        embedder: parse_embedder(args)?,
        backend: parse_backend(args, "pjrt")?,
        seed: args.get_u64("seed", 7).map_err(anyhow::Error::msg)?,
        walks_per_node: args.get_usize("walks", 15).map_err(anyhow::Error::msg)? as u32,
        walk_length: args
            .get_usize("walk-length", 30)
            .map_err(anyhow::Error::msg)?,
        threads: args
            .get_usize("threads", kcore_embed::util::pool::default_threads())
            .map_err(anyhow::Error::msg)?,
        ..Default::default()
    };
    cfg.k0 = match args.get_usize("k0", usize::MAX).map_err(anyhow::Error::msg)? {
        usize::MAX => None,
        k => Some(k as u32),
    };
    cfg.train_threads = args
        .get_usize("train-threads", 0)
        .map_err(anyhow::Error::msg)?;
    cfg.sgns.dim = args.get_usize("dim", 128).map_err(anyhow::Error::msg)?;
    cfg.sgns.window = args.get_usize("window", 4).map_err(anyhow::Error::msg)?;
    cfg.sgns.epochs = args.get_usize("epochs", 1).map_err(anyhow::Error::msg)?;
    cfg.corpus_shards = args.get_usize("shards", 0).map_err(anyhow::Error::msg)?;
    cfg.corpus_budget_mb = args
        .get_usize("corpus-budget-mb", 0)
        .map_err(anyhow::Error::msg)?;
    cfg.spill_dir = args.opt_str("spill-dir").map(PathBuf::from);
    // Reject degenerate walk parameters (node2vec p/q <= 0, zero-length
    // walks) here at parse time, not deep inside the walk engine.
    cfg.validate()?;
    Ok(cfg)
}

/// Instantiate the PJRT runtime only when the config needs it.
fn maybe_runtime(cfg_backend: Backend) -> Result<Option<(Runtime, Manifest)>> {
    if cfg_backend != Backend::Pjrt {
        return Ok(None);
    }
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let rt = Runtime::cpu()?;
    eprintln!("pjrt: platform={}", rt.platform());
    Ok(Some((rt, manifest)))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let out = args
        .opt_str("out")
        .ok_or_else(|| anyhow::anyhow!("--out required"))?;
    io::save_edge_list(&g, Path::new(&out))?;
    println!("{}", metrics::describe(&g));
    println!("wrote {out}");
    args.finish().map_err(anyhow::Error::msg)
}

fn cmd_describe(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    println!("{}", metrics::describe(&g));
    let d = core_decomposition(&g);
    println!("degeneracy: {}", d.degeneracy);
    println!(
        "largest connected component: {} nodes",
        kcore_embed::graph::connectivity::largest_component(&g).len()
    );
    println!("shell histogram (core index: nodes):");
    for (k, n) in subcore::shell_histogram(&d) {
        println!("  {k:>4}: {n}");
    }
    args.finish().map_err(anyhow::Error::msg)
}

fn cmd_embed(args: &Args) -> Result<()> {
    // The tracer opens before the graph loads so the `load` phase is
    // on the trace too (it dominates for big edge lists).
    let trace_out = args.opt_str("trace-out").map(PathBuf::from);
    let tracer = Tracer::from_trace_out(trace_out.as_deref())?;
    let g = {
        let _s = tracer.span("load");
        load_graph(args)?
    };
    let mut cfg = build_config(args)?;
    cfg.export_store = args.opt_str("store").map(PathBuf::from);
    cfg.notify_daemon = args.opt_str("notify");
    // Crash-safe jobs (DESIGN.md §Robustness): --job-dir makes every
    // phase commit durably to a manifest and lets a rerun resume;
    // --ckpt-every N sets the serial trainer's epoch checkpoint cadence.
    cfg.job_dir = args.opt_str("job-dir").map(PathBuf::from);
    cfg.ckpt_every = args
        .get_usize("ckpt-every", 0)
        .map_err(anyhow::Error::msg)?;
    cfg.trace_out = trace_out;
    cfg.validate()?; // --notify without --store is a usage error
    let out = args
        .opt_str("out")
        .ok_or_else(|| anyhow::anyhow!("--out required"))?;
    args.finish().map_err(anyhow::Error::msg)?;
    let rt = maybe_runtime(cfg.backend)?;
    let rt_ref = rt.as_ref().map(|(r, m)| (r, m));
    let res = run_pipeline_traced(&g, &cfg, rt_ref, &tracer)?;
    println!(
        "embedded {} nodes (core size {}, k0 {:?}, degeneracy {}) in {:.2}s",
        res.embedding.n(),
        res.core_size,
        res.k0_used,
        res.degeneracy,
        res.total_secs()
    );
    for (phase, secs) in res.timer.phases() {
        println!("  {phase}: {secs:.2}s");
    }
    let cs = res.corpus_stats;
    let spill_note = if cs.spilled_shards > 0 {
        format!(
            ", {} shards spilled ({:.1} MiB to disk)",
            cs.spilled_shards,
            cs.spilled_bytes as f64 / (1u64 << 20) as f64
        )
    } else {
        String::new()
    };
    println!(
        "corpus: {} walks, {} tokens, peak resident {:.1} MiB{spill_note}",
        res.n_walks,
        res.n_tokens,
        cs.peak_resident_bytes as f64 / (1u64 << 20) as f64
    );
    if !res.loss_curve.is_empty() {
        println!("loss curve (pairs, mean loss):");
        for p in &res.loss_curve {
            println!("  {:>10} {:.4}", p.pairs, p.mean_loss);
        }
    }
    io::save_embeddings(
        res.embedding.data(),
        res.embedding.n(),
        res.embedding.dim(),
        Path::new(&out),
    )?;
    println!("wrote {out}");
    if let Some(store) = &cfg.export_store {
        println!("wrote serving artifact {}", store.display());
    }
    if let Some(path) = &cfg.trace_out {
        println!("wrote trace {}", path.display());
        if let Some(summary) = &res.trace_summary {
            println!("trace summary: {}", summary.to_string());
        }
    }
    if let Some(ack) = &res.daemon_ack {
        println!("daemon swap: {ack}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let g = load_graph(args)?;
    let base_cfg = build_config(args)?;
    let remove = args.get_f64("remove", 0.10).map_err(anyhow::Error::msg)?;
    let trials = args.get_usize("trials", 5).map_err(anyhow::Error::msg)?;
    let cores = args
        .get_usize_list("cores", &[])
        .map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let rt = maybe_runtime(base_cfg.backend)?;
    let rt_ref = rt.as_ref().map(|(r, m)| (r, m));
    let exp = Experiment {
        graph: &g,
        remove_frac: remove,
        trials,
        seed: base_cfg.seed,
        runtime: rt_ref,
    };
    // Baseline: plain DeepWalk on the full graph.
    let baseline = exp.run_row(&PipelineConfig {
        embedder: Embedder::DeepWalk,
        k0: None,
        ..base_cfg.clone()
    })?;
    let mut rows = Vec::new();
    if base_cfg.embedder != Embedder::DeepWalk || base_cfg.k0.is_some() {
        rows.push(exp.run_row(&base_cfg)?);
    }
    for &k0 in &cores {
        let mut cfg = base_cfg.clone();
        cfg.k0 = Some(k0 as u32);
        rows.push(exp.run_row(&cfg)?);
    }
    let t = render_table(
        &format!(
            "Link prediction, {:.0}% of edges removed, {} trials",
            remove * 100.0,
            trials
        ),
        &baseline,
        &rows,
    );
    print!("{}", t.render());
    Ok(())
}

/// Load an exported artifact per the shared `--store`/`--in-memory`
/// flags (mmap is the default: O(1) resident startup).
fn load_store(args: &Args) -> Result<EmbeddingStore> {
    let path = args
        .opt_str("store")
        .ok_or_else(|| anyhow::anyhow!("--store required"))?;
    let path = Path::new(&path);
    if args.has_flag("in-memory") {
        EmbeddingStore::open_in_memory(path)
    } else {
        EmbeddingStore::open_mmap(path)
    }
}

fn parse_metric(args: &Args) -> Result<Metric> {
    let name = args.get_str("metric", "cosine");
    Metric::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown metric {name:?} (dot|cosine)"))
}

fn parse_edge_op(args: &Args) -> Result<EdgeOp> {
    let name = args.get_str("op", "hadamard");
    EdgeOp::by_name(&name).ok_or_else(|| {
        anyhow::anyhow!("unknown operator {name:?} (concat|average|hadamard|l1|l2)")
    })
}

/// Fit the edge scorer when a serving graph was supplied.
fn maybe_scorer(
    graph: Option<&Graph>,
    store: &EmbeddingStore,
    op: EdgeOp,
    seed: u64,
) -> Result<Option<EdgeScorer>> {
    match graph {
        None => Ok(None),
        Some(g) => Ok(Some(EdgeScorer::fit(
            g,
            store,
            &EdgeScorerParams {
                op,
                seed,
                ..Default::default()
            },
        )?)),
    }
}

fn print_response(r: &Response) {
    match r {
        Response::Neighbors { node, hits } => {
            let cells: Vec<String> =
                hits.iter().map(|(v, s)| format!("{v}:{s:.4}")).collect();
            println!("nn {node} -> {}", cells.join(" "));
        }
        Response::EdgeScore { u, v, p } => println!("edge {u} {v} -> {p:.4}"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let graph = maybe_load_graph(args)?;
    let metric = parse_metric(args)?;
    let op = parse_edge_op(args)?;
    let quantized = args.has_flag("quantized");
    let batch = args.get_usize("batch", 64).map_err(anyhow::Error::msg)?;
    let default_k = args.get_usize("top-k", 10).map_err(anyhow::Error::msg)?;
    let threads = args
        .get_usize("threads", kcore_embed::util::pool::default_threads())
        .map_err(anyhow::Error::msg)?;
    let requests_path = args.opt_str("requests");
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;
    let listen = match (args.opt_str("listen"), args.opt_str("listen-tcp")) {
        (Some(_), Some(_)) => bail!("specify at most one of --listen / --listen-tcp"),
        (Some(sock), None) => Some(ServeAddr::Unix(PathBuf::from(sock))),
        (None, Some(tcp)) => Some(ServeAddr::Tcp(tcp)),
        (None, None) => None,
    };
    if let Some(listen) = listen {
        // Persistent daemon mode: generations + transport serve loop.
        if requests_path.is_some() {
            bail!("--requests is batch-mode only; daemon clients send requests over the socket");
        }
        if args.opt_str("top-k").is_some() {
            bail!("--top-k is batch-mode only; daemon clients pass k per 'nn NODE K' request");
        }
        let store_path = args
            .opt_str("store")
            .ok_or_else(|| anyhow::anyhow!("--store required"))?;
        let in_memory = args.has_flag("in-memory");
        let max_conns = args.get_usize("max-conns", 256).map_err(anyhow::Error::msg)?;
        let timeout_ms = args
            .get_u64("read-timeout-ms", 30_000)
            .map_err(anyhow::Error::msg)?;
        let trace_out = args.opt_str("trace-out").map(PathBuf::from);
        let max_inflight = args.get_usize("max-inflight", 0).map_err(anyhow::Error::msg)?;
        let accept_model = AcceptModel::parse(&args.get_str("accept-model", "threads"))?;
        let fault_spec = args.opt_str("faults");
        let fault_seed = args.get_u64("fault-seed", 0).map_err(anyhow::Error::msg)?;
        args.finish().map_err(anyhow::Error::msg)?;
        if let Some(spec) = fault_spec {
            kcore_embed::obs::faults::global()
                .configure(&spec, fault_seed)
                .context("parsing --faults")?;
        }
        if kcore_embed::obs::faults::armed() {
            eprintln!("daemon: FAILPOINTS ARMED (chaos drill — not a production configuration)");
        }
        let opts = GenerationOpts {
            serve: ServeOpts {
                metric,
                quantized,
                batch,
                topk: TopKParams {
                    threads,
                    ..Default::default()
                },
            },
            op,
            seed,
            in_memory,
            verify_on_load: true,
            // Daemons keep a `<store>.current` lineage file so a
            // restart reopens the last-good generation (health reports
            // `recovered: true`). Batch `serve`/`query` leave it off.
            lineage: true,
        };
        let has_graph = graph.is_some();
        let gens = GenerationStore::open(Path::new(&store_path), graph, opts)?;
        let gen = gens.current();
        eprintln!(
            "daemon: {} from {}, edge scorer {}, listening on {listen} ({})",
            gen.stats_line(),
            store_path,
            if has_graph { "fitted" } else { "absent" },
            listen.transport(),
        );
        // Thread budget: --threads controls one scan's fan-out; the
        // batch-level fan-out fills whatever cores remain, so nested
        // pool::parallel_tasks never oversubscribes threads*batch.
        let cores = kcore_embed::util::pool::default_threads();
        let server_opts = ServerOpts {
            listen,
            batch_threads: (cores / threads.max(1)).max(1),
            read_timeout: if timeout_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(timeout_ms))
            },
            max_conns,
            max_inflight,
            trace: Tracer::from_trace_out(trace_out.as_deref())?,
            accept_model,
        };
        let stats = run_server(Arc::new(gens), &server_opts)?;
        eprintln!(
            "daemon: clean shutdown after {} connections, {} requests, {} swaps, {} rejected, \
             {} panics caught, {} shed",
            stats.connections,
            stats.requests,
            stats.swaps,
            stats.rejected,
            stats.panics,
            stats.shed
        );
        return Ok(());
    }
    let store = load_store(args)?;
    args.finish().map_err(anyhow::Error::msg)?;

    eprintln!(
        "store: {} nodes x {} dims, cores {}, {} view{}",
        store.n(),
        store.dim(),
        if store.has_cores() { "present" } else { "absent" },
        if store.is_mmap() { "mmap" } else { "in-memory" },
        if quantized { ", 8-bit quantized scan" } else { "" },
    );
    let scorer = maybe_scorer(graph.as_ref(), &store, op, seed)?;
    let has_scorer = scorer.is_some();
    let opts = ServeOpts {
        metric,
        quantized,
        batch,
        topk: TopKParams {
            threads,
            ..Default::default()
        },
    };
    let mut svc = QueryService::new(store, opts);
    if let Some(s) = scorer {
        svc = svc.with_scorer(s);
    }
    if has_scorer {
        eprintln!("edge scorer: fitted ({} operator)", op.name());
    }

    let text = match requests_path {
        Some(p) => std::fs::read_to_string(&p).with_context(|| format!("reading {p}"))?,
        None => {
            let mut buf = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
            buf
        }
    };
    let mut requests = Vec::new();
    for line in text.lines() {
        // Bare `nn NODE` lines pick up the --top-k default.
        let line = line.trim();
        let toks: Vec<&str> = line.split_whitespace().collect();
        let expanded;
        let line = if toks.len() == 2 && toks[0] == "nn" {
            expanded = format!("{line} {default_k}");
            &expanded
        } else {
            line
        };
        if let Some(req) = Request::parse(line)? {
            requests.push(req);
        }
    }
    if requests.is_empty() {
        bail!("no requests (expected 'nn NODE [K]' / 'edge U V' lines)");
    }
    let (responses, reports) = svc.run_all(&requests)?;
    for r in &responses {
        print_response(r);
    }
    let table = render_latency_table(
        &format!(
            "Serve latency, {} requests in {} batches (batch size {batch})",
            requests.len(),
            reports.len()
        ),
        &reports,
    );
    eprint!("{}", table.render());
    Ok(())
}

/// `query --connect`/`--connect-tcp`: drive a running daemon over
/// either transport.
fn cmd_query_connect(args: &Args, addr: &ServeAddr) -> Result<()> {
    let control = args.opt_str("control");
    let k = args.get_usize("top-k", 10).map_err(anyhow::Error::msg)?;
    let node = match args.get_usize("node", usize::MAX).map_err(anyhow::Error::msg)? {
        usize::MAX => None,
        v => Some(
            u32::try_from(v).map_err(|_| anyhow::anyhow!("--node {v} exceeds u32 range"))?,
        ),
    };
    let edge = args.opt_u32_pair("edge").map_err(anyhow::Error::msg)?;
    let store = args.opt_str("store");
    args.finish().map_err(anyhow::Error::msg)?;
    let lines: Vec<String> = match control.as_deref() {
        Some("swap") => {
            let p = store
                .ok_or_else(|| anyhow::anyhow!("--control swap needs --store ARTIFACT"))?;
            println!("{}", notify_swap(addr, Path::new(&p))?);
            return Ok(());
        }
        Some("stats") => vec![ClientMsg::Stats.encode()],
        Some("metrics") => vec![ClientMsg::Metrics.encode()],
        Some("health") => vec![ClientMsg::Health.encode()],
        Some("shutdown") => vec![ClientMsg::Shutdown.encode()],
        Some(x) => bail!("unknown --control {x:?} (swap|stats|metrics|health|shutdown)"),
        None => {
            let mut ls = Vec::new();
            if let Some(v) = node {
                ls.push(ClientMsg::Query(Request::Neighbors { node: v, k }).encode());
            }
            if let Some((u, v)) = edge {
                ls.push(ClientMsg::Query(Request::EdgeScore { u, v }).encode());
            }
            if ls.is_empty() {
                bail!(
                    "specify --node V and/or --edge U,V (or --control \
                     swap|stats|metrics|health|shutdown)"
                );
            }
            ls
        }
    };
    for reply in client_exchange(addr, &lines)? {
        println!("{reply}");
    }
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let addr = match (args.opt_str("connect"), args.opt_str("connect-tcp")) {
        (Some(_), Some(_)) => bail!("specify at most one of --connect / --connect-tcp"),
        (Some(s), None) => Some(ServeAddr::parse(&s)),
        (None, Some(t)) => Some(ServeAddr::Tcp(t)),
        (None, None) => None,
    };
    if let Some(addr) = addr {
        return cmd_query_connect(args, &addr);
    }
    let graph = maybe_load_graph(args)?;
    let metric = parse_metric(args)?;
    let op = parse_edge_op(args)?;
    let quantized = args.has_flag("quantized");
    let k = args.get_usize("top-k", 10).map_err(anyhow::Error::msg)?;
    let node = match args.get_usize("node", usize::MAX).map_err(anyhow::Error::msg)? {
        usize::MAX => None,
        v => Some(
            u32::try_from(v).map_err(|_| anyhow::anyhow!("--node {v} exceeds u32 range"))?,
        ),
    };
    let edge = args.opt_u32_pair("edge").map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;
    let store = load_store(args)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let mut requests = Vec::new();
    if let Some(v) = node {
        requests.push(Request::Neighbors { node: v, k });
    }
    if let Some((u, v)) = edge {
        requests.push(Request::EdgeScore { u, v });
    }
    if requests.is_empty() {
        bail!("specify --node V and/or --edge U,V");
    }
    let scorer = if edge.is_some() {
        let g = graph.as_ref().ok_or_else(|| {
            anyhow::anyhow!("--edge scoring needs the serving graph (--graph or --edges)")
        })?;
        maybe_scorer(Some(g), &store, op, seed)?
    } else {
        None
    };
    let opts = ServeOpts {
        metric,
        quantized,
        ..Default::default()
    };
    let mut svc = QueryService::new(store, opts);
    if let Some(s) = scorer {
        svc = svc.with_scorer(s);
    }
    let (responses, _) = svc.run_all(&requests)?;
    for r in &responses {
        print_response(r);
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args.get_str("exp", "all");
    let quick = args.has_flag("quick");
    let mut opts = if quick {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    };
    opts.trials = args
        .get_usize("trials", opts.trials)
        .map_err(anyhow::Error::msg)?;
    opts.walks_per_node = args
        .get_usize("walks", opts.walks_per_node as usize)
        .map_err(anyhow::Error::msg)? as u32;
    // Benches default to the native backend (CPU baseline semantics,
    // like the paper's gensim runs); `--backend pjrt` opts into the
    // device path.
    opts.backend = parse_backend(args, "native")?;
    opts.seed = args.get_u64("seed", opts.seed).map_err(anyhow::Error::msg)?;
    opts.out_dir = PathBuf::from(args.get_str("out-dir", "bench_out"));
    args.finish().map_err(anyhow::Error::msg)?;
    if !BENCH_NAMES.contains(&exp.as_str()) {
        bail!("unknown --exp {exp:?}; known: {BENCH_NAMES:?}");
    }
    let rt = maybe_runtime(opts.backend)?;
    let rt_ref = rt.as_ref().map(|(r, m)| (r, m));
    let out = run_bench(&exp, &opts, rt_ref).context("running benchmark")?;
    print!("{out}");
    eprintln!("(artifacts written to {})", opts.out_dir.display());
    Ok(())
}
