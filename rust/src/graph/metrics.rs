//! Structural graph metrics used for dataset calibration and reporting:
//! degree statistics, (sampled) clustering coefficient, degree
//! assortativity.

use super::csr::Graph;
use crate::util::rng::Rng;

/// Degree histogram as (degree, count), sorted by degree.
pub fn degree_histogram(g: &Graph) -> Vec<(usize, usize)> {
    crate::util::stats::int_histogram((0..g.n_nodes() as u32).map(|v| g.degree(v)))
}

/// Sampled global clustering coefficient: probability that a random
/// wedge (path of length 2) is closed. Exact when `samples >= #wedges`
/// would be expensive; sampling error is fine for calibration.
pub fn global_clustering(g: &Graph, samples: usize, rng: &mut Rng) -> f64 {
    let candidates: Vec<u32> = (0..g.n_nodes() as u32)
        .filter(|&v| g.degree(v) >= 2)
        .collect();
    if candidates.is_empty() {
        return 0.0;
    }
    let mut closed = 0usize;
    let mut total = 0usize;
    for _ in 0..samples {
        let v = *rng.choose(&candidates);
        let nbrs = g.neighbors(v);
        let i = rng.gen_index(nbrs.len());
        let mut j = rng.gen_index(nbrs.len() - 1);
        if j >= i {
            j += 1;
        }
        total += 1;
        if g.has_edge(nbrs[i], nbrs[j]) {
            closed += 1;
        }
    }
    closed as f64 / total as f64
}

/// Degree assortativity: Pearson correlation of endpoint degrees over
/// all edges (both orientations, the standard Newman definition).
pub fn degree_assortativity(g: &Graph) -> f64 {
    let mut n = 0f64;
    let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0f64, 0f64, 0f64, 0f64, 0f64);
    for (u, v) in g.edges() {
        for (a, b) in [(u, v), (v, u)] {
            let x = g.degree(a) as f64;
            let y = g.degree(b) as f64;
            n += 1.0;
            sx += x;
            sy += y;
            sxx += x * x;
            syy += y * y;
            sxy += x * y;
        }
    }
    if n == 0.0 {
        return 0.0;
    }
    let cov = sxy / n - (sx / n) * (sy / n);
    let vx = sxx / n - (sx / n) * (sx / n);
    let vy = syy / n - (sy / n) * (sy / n);
    if vx <= 0.0 || vy <= 0.0 {
        0.0
    } else {
        cov / (vx * vy).sqrt()
    }
}

/// One-line structural summary used by the CLI `describe` command.
pub fn describe(g: &Graph) -> String {
    format!(
        "nodes={} edges={} avg_deg={:.2} max_deg={} isolated={}",
        g.n_nodes(),
        g.n_edges(),
        g.avg_degree(),
        g.max_degree(),
        g.isolated_nodes().len(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn histogram_on_star() {
        let g = generators::star(5);
        let h = degree_histogram(&g);
        assert_eq!(h, vec![(1, 4), (4, 1)]);
    }

    #[test]
    fn clustering_extremes() {
        let mut rng = Rng::new(1);
        let k = generators::complete(10);
        assert!((global_clustering(&k, 2000, &mut rng) - 1.0).abs() < 1e-9);
        let s = generators::star(20);
        assert_eq!(global_clustering(&s, 2000, &mut rng), 0.0);
        let empty = crate::graph::csr::Graph::from_edges(3, &[]);
        assert_eq!(global_clustering(&empty, 100, &mut rng), 0.0);
    }

    #[test]
    fn assortativity_sign_on_star() {
        // Stars are maximally disassortative.
        let s = generators::star(20);
        assert!(degree_assortativity(&s) < -0.99);
        // Ring: all degrees equal -> degenerate variance -> 0.
        let r = generators::ring(10);
        assert_eq!(degree_assortativity(&r), 0.0);
    }

    #[test]
    fn describe_contains_counts() {
        let g = generators::ring(5);
        let d = describe(&g);
        assert!(d.contains("nodes=5") && d.contains("edges=5"));
    }
}
