//! Connected components: union-find plus BFS utilities.
//!
//! The paper restricts embedding to the largest connected component (§2)
//! and its Fig 6 scenario hinges on whether a k-core is connected, so
//! connectivity checks show up throughout the pipeline.

use super::csr::Graph;

/// Disjoint-set forest with union by rank + path halving.
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).collect(),
            rank: vec![0; n],
            components: n,
        }
    }

    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }

    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (ra, rb) = if self.rank[ra as usize] < self.rank[rb as usize] {
            (rb, ra)
        } else {
            (ra, rb)
        };
        self.parent[rb as usize] = ra;
        if self.rank[ra as usize] == self.rank[rb as usize] {
            self.rank[ra as usize] += 1;
        }
        self.components -= 1;
        true
    }

    pub fn n_components(&self) -> usize {
        self.components
    }

    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }
}

/// Component id per node (ids are 0..k, ordered by first appearance).
pub fn connected_components(g: &Graph) -> Vec<u32> {
    let n = g.n_nodes();
    let mut comp = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for start in 0..n as u32 {
        if comp[start as usize] != u32::MAX {
            continue;
        }
        comp[start as usize] = next;
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            for &v in g.neighbors(u) {
                if comp[v as usize] == u32::MAX {
                    comp[v as usize] = next;
                    queue.push_back(v);
                }
            }
        }
        next += 1;
    }
    comp
}

/// Number of connected components.
pub fn n_components(g: &Graph) -> usize {
    if g.n_nodes() == 0 {
        return 0;
    }
    connected_components(g).iter().max().map(|&m| m as usize + 1).unwrap()
}

pub fn is_connected(g: &Graph) -> bool {
    g.n_nodes() <= 1 || n_components(g) == 1
}

/// Node list of the largest connected component (sorted).
pub fn largest_component(g: &Graph) -> Vec<u32> {
    let comp = connected_components(g);
    let mut counts = std::collections::HashMap::new();
    for &c in &comp {
        *counts.entry(c).or_insert(0usize) += 1;
    }
    let best = counts
        .into_iter()
        .max_by_key(|&(c, n)| (n, std::cmp::Reverse(c)))
        .map(|(c, _)| c)
        .unwrap_or(0);
    (0..g.n_nodes() as u32)
        .filter(|&v| comp[v as usize] == best)
        .collect()
}

/// Shortest path from `src` to `dst` (inclusive), or None if
/// unreachable. BFS with parent reconstruction.
pub fn bfs_path(g: &Graph, src: u32, dst: u32) -> Option<Vec<u32>> {
    if src == dst {
        return Some(vec![src]);
    }
    let mut parent = vec![u32::MAX; g.n_nodes()];
    parent[src as usize] = src;
    let mut queue = std::collections::VecDeque::from([src]);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            if parent[v as usize] == u32::MAX {
                parent[v as usize] = u;
                if v == dst {
                    let mut path = vec![dst];
                    let mut cur = dst;
                    while cur != src {
                        cur = parent[cur as usize];
                        path.push(cur);
                    }
                    path.reverse();
                    return Some(path);
                }
                queue.push_back(v);
            }
        }
    }
    None
}

/// BFS hop distances from `src` (u32::MAX for unreachable).
pub fn bfs_distances(g: &Graph, src: u32) -> Vec<u32> {
    let mut dist = vec![u32::MAX; g.n_nodes()];
    dist[src as usize] = 0;
    let mut queue = std::collections::VecDeque::from([src]);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            if dist[v as usize] == u32::MAX {
                dist[v as usize] = dist[u as usize] + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_triangles() -> Graph {
        Graph::from_edges(7, &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)])
    }

    #[test]
    fn components_found() {
        let g = two_triangles();
        let comp = connected_components(&g);
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[3], comp[4]);
        assert_ne!(comp[0], comp[3]);
        assert_ne!(comp[6], comp[0]);
        assert_eq!(n_components(&g), 3); // two triangles + isolated node 6
        assert!(!is_connected(&g));
    }

    #[test]
    fn largest_component_ties_and_sizes() {
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (3, 4)]);
        assert_eq!(largest_component(&g), vec![0, 1, 2]);
    }

    #[test]
    fn single_node_connected() {
        let g = Graph::from_edges(1, &[]);
        assert!(is_connected(&g));
        let empty = Graph::from_edges(0, &[]);
        assert_eq!(n_components(&empty), 0);
    }

    #[test]
    fn union_find_tracks_components() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.n_components(), 5);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0));
        uf.union(2, 3);
        uf.union(0, 3);
        assert_eq!(uf.n_components(), 2);
        assert!(uf.same(1, 2));
        assert!(!uf.same(0, 4));
    }

    #[test]
    fn bfs_path_found_and_shortest() {
        let g = Graph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (0, 4), (4, 3)]);
        let p = bfs_path(&g, 0, 3).unwrap();
        assert_eq!(p.len(), 3); // 0-4-3 beats 0-1-2-3
        assert_eq!(p[0], 0);
        assert_eq!(*p.last().unwrap(), 3);
        for w in p.windows(2) {
            assert!(g.has_edge(w[0], w[1]));
        }
        assert_eq!(bfs_path(&g, 0, 5), None);
        assert_eq!(bfs_path(&g, 2, 2), Some(vec![2]));
    }

    #[test]
    fn bfs_distances_on_path() {
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3)]);
        let d = bfs_distances(&g, 0);
        assert_eq!(d, vec![0, 1, 2, 3, u32::MAX]);
    }
}
