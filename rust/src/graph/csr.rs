//! Immutable CSR (compressed sparse row) graph.
//!
//! All algorithms in the library (k-core decomposition, random walks,
//! propagation, evaluation) run on this structure. Graphs are undirected
//! and unweighted, like the paper's datasets (§3.1.1): every edge is
//! stored in both adjacency rows; per-row targets are sorted so
//! `has_edge` is a binary search and neighbour slices are deterministic.

/// Undirected, unweighted graph in CSR form. Node ids are `u32` and
/// contiguous in `[0, n)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    offsets: Vec<u32>, // n + 1
    targets: Vec<u32>, // 2 * m, sorted within each row
}

impl Graph {
    /// Build from an edge list. Self-loops are rejected; duplicate edges
    /// (in either orientation) are deduplicated.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        assert!(n <= u32::MAX as usize - 1, "graph too large for u32 ids");
        let mut deg = vec![0u32; n];
        let mut canon: Vec<(u32, u32)> = Vec::with_capacity(edges.len());
        for &(a, b) in edges {
            assert!(
                (a as usize) < n && (b as usize) < n,
                "edge ({a},{b}) out of range for n={n}"
            );
            assert!(a != b, "self-loop at node {a}");
            canon.push((a.min(b), a.max(b)));
        }
        canon.sort_unstable();
        canon.dedup();
        for &(a, b) in &canon {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + deg[v];
        }
        let mut targets = vec![0u32; offsets[n] as usize];
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        for &(a, b) in &canon {
            targets[cursor[a as usize] as usize] = b;
            cursor[a as usize] += 1;
            targets[cursor[b as usize] as usize] = a;
            cursor[b as usize] += 1;
        }
        // Rows are sorted because canon is sorted lexicographically and we
        // append targets in increasing order per row for the first
        // endpoint, but the second-endpoint appends can interleave, so
        // sort each row explicitly (cheap, m log deg).
        for v in 0..n {
            let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
            targets[s..e].sort_unstable();
        }
        Graph { offsets, targets }
    }

    /// Number of nodes.
    #[inline]
    pub fn n_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn n_edges(&self) -> usize {
        self.targets.len() / 2
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Sorted neighbour slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.targets[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// O(log deg) membership test.
    #[inline]
    pub fn has_edge(&self, a: u32, b: u32) -> bool {
        self.neighbors(a).binary_search(&b).is_ok()
    }

    /// Iterate undirected edges once each, as (u, v) with u < v.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n_nodes() as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .copied()
                .filter(move |&v| u < v)
                .map(move |v| (u, v))
        })
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n_nodes() as u32)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Average degree (2m / n).
    pub fn avg_degree(&self) -> f64 {
        if self.n_nodes() == 0 {
            0.0
        } else {
            self.targets.len() as f64 / self.n_nodes() as f64
        }
    }

    /// Nodes with degree zero.
    pub fn isolated_nodes(&self) -> Vec<u32> {
        (0..self.n_nodes() as u32)
            .filter(|&v| self.degree(v) == 0)
            .collect()
    }

    /// Structural identity digest: FNV-1a 64 over the node count and
    /// both CSR arrays. Because construction canonicalizes (edges
    /// deduplicated, rows sorted), two graphs fingerprint equal iff
    /// they have the same node set and edge set — the resume gate the
    /// job manifest uses so sealed phase outputs are never reused for
    /// a different input graph.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fsio::Fnv1a64::new();
        h.update(&(self.n_nodes() as u64).to_le_bytes());
        for &o in &self.offsets {
            h.update(&o.to_le_bytes());
        }
        for &t in &self.targets {
            h.update(&t.to_le_bytes());
        }
        h.finish()
    }

    /// Induced subgraph on `nodes` (need not be sorted; duplicates
    /// rejected). Returns the subgraph plus the old-id list indexed by
    /// new id (`new -> old`); the inverse map is derivable.
    pub fn induced_subgraph(&self, nodes: &[u32]) -> (Graph, Vec<u32>) {
        let n_old = self.n_nodes();
        let mut new_id = vec![u32::MAX; n_old];
        for (new, &old) in nodes.iter().enumerate() {
            assert!(
                new_id[old as usize] == u32::MAX,
                "duplicate node {old} in induced_subgraph"
            );
            new_id[old as usize] = new as u32;
        }
        let mut edges = Vec::new();
        for (new_u, &old_u) in nodes.iter().enumerate() {
            for &old_v in self.neighbors(old_u) {
                let new_v = new_id[old_v as usize];
                if new_v != u32::MAX && (new_u as u32) < new_v {
                    edges.push((new_u as u32, new_v));
                }
            }
        }
        (Graph::from_edges(nodes.len(), &edges), nodes.to_vec())
    }

    /// Remove the given undirected edges (orientation-insensitive),
    /// returning the remaining graph. Unknown edges are ignored.
    pub fn remove_edges(&self, removed: &[(u32, u32)]) -> Graph {
        use std::collections::HashSet;
        let gone: HashSet<(u32, u32)> = removed
            .iter()
            .map(|&(a, b)| (a.min(b), a.max(b)))
            .collect();
        let kept: Vec<(u32, u32)> = self.edges().filter(|e| !gone.contains(e)).collect();
        Graph::from_edges(self.n_nodes(), &kept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> Graph {
        // 0-1-2 triangle, 2-3 tail.
        Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)])
    }

    #[test]
    fn basic_shape() {
        let g = triangle_plus_tail();
        assert_eq!(g.n_nodes(), 4);
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.max_degree(), 3);
        assert!((g.avg_degree() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn dedup_and_orientation() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 1), (1, 2)]);
        assert_eq!(g.n_edges(), 2);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loops() {
        Graph::from_edges(2, &[(1, 1)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        Graph::from_edges(2, &[(0, 5)]);
    }

    #[test]
    fn edges_iterator_each_once() {
        let g = triangle_plus_tail();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 2), (2, 3)]);
    }

    #[test]
    fn isolated_nodes_listed() {
        let g = Graph::from_edges(5, &[(0, 1)]);
        assert_eq!(g.isolated_nodes(), vec![2, 3, 4]);
    }

    #[test]
    fn induced_subgraph_maps_ids() {
        let g = triangle_plus_tail();
        let (sub, new_to_old) = g.induced_subgraph(&[2, 0, 1]);
        assert_eq!(sub.n_nodes(), 3);
        assert_eq!(sub.n_edges(), 3); // the triangle survives
        assert_eq!(new_to_old, vec![2, 0, 1]);
        // Node 3's tail edge is dropped.
        assert!(sub.has_edge(0, 1) && sub.has_edge(0, 2) && sub.has_edge(1, 2));
    }

    #[test]
    fn remove_edges_either_orientation() {
        let g = triangle_plus_tail();
        let g2 = g.remove_edges(&[(1, 0), (3, 2)]);
        assert_eq!(g2.n_edges(), 2);
        assert!(!g2.has_edge(0, 1));
        assert!(!g2.has_edge(2, 3));
        assert!(g2.has_edge(0, 2));
        assert_eq!(g2.n_nodes(), 4); // node count preserved
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(0, &[]);
        assert_eq!(g.n_nodes(), 0);
        assert_eq!(g.n_edges(), 0);
        assert_eq!(g.max_degree(), 0);
    }

    #[test]
    fn fingerprint_tracks_structure_not_input_order() {
        // Same edge set in any orientation/order: same identity.
        let a = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let b = Graph::from_edges(4, &[(3, 2), (1, 0), (2, 1), (0, 1)]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Different edge set, node count, or even an extra isolated
        // node: different identity.
        let c = Graph::from_edges(4, &[(0, 1), (1, 2), (0, 3)]);
        assert_ne!(a.fingerprint(), c.fingerprint());
        let d = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3)]);
        assert_ne!(a.fingerprint(), d.fingerprint());
    }
}
