//! Graph and embedding file I/O.
//!
//! Formats:
//! - edge list: whitespace-separated `u v` per line, `#` comments,
//!   node count inferred (max id + 1) or given;
//! - embeddings: TSV `node \t x0 \t x1 ...` with a `# dim=D` header.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::csr::Graph;

/// Load an edge-list file. If `n_nodes` is None the node count is
/// `max_id + 1`.
pub fn load_edge_list(path: &Path, n_nodes: Option<usize>) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening edge list {}", path.display()))?;
    let reader = std::io::BufReader::new(file);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut max_id = 0u32;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("{}:{}: expected 'u v'", path.display(), lineno + 1),
        };
        let a: u32 = a
            .parse()
            .with_context(|| format!("{}:{}: bad node id {a:?}", path.display(), lineno + 1))?;
        let b: u32 = b
            .parse()
            .with_context(|| format!("{}:{}: bad node id {b:?}", path.display(), lineno + 1))?;
        if a == b {
            continue; // drop self-loops silently, like networkx read_edgelist usage in the paper
        }
        max_id = max_id.max(a).max(b);
        edges.push((a, b));
    }
    let n = n_nodes.unwrap_or(if edges.is_empty() { 0 } else { max_id as usize + 1 });
    Ok(Graph::from_edges(n, &edges))
}

/// Save a graph as an edge list (u < v, one edge per line).
pub fn save_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# nodes={} edges={}", g.n_nodes(), g.n_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

/// Save an embedding matrix (`n x dim`, row-major f32) as TSV.
pub fn save_embeddings(emb: &[f32], n: usize, dim: usize, path: &Path) -> Result<()> {
    assert_eq!(emb.len(), n * dim);
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# dim={dim}")?;
    for (v, row) in emb.chunks_exact(dim).enumerate() {
        write!(w, "{v}")?;
        for x in row {
            write!(w, "\t{x}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Load embeddings saved by [`save_embeddings`]. Returns (matrix, n, dim).
pub fn load_embeddings(path: &Path) -> Result<(Vec<f32>, usize, usize)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut dim: Option<usize> = None;
    let mut rows: Vec<(usize, Vec<f32>)> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(d) = rest.trim().strip_prefix("dim=") {
                dim = Some(d.parse().context("bad dim header")?);
            }
            continue;
        }
        let mut it = line.split('\t');
        let v: usize = it
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad node id", lineno + 1))?;
        let row: Vec<f32> = it
            .map(|t| t.parse::<f32>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("line {}: bad float", lineno + 1))?;
        rows.push((v, row));
    }
    let dim = dim.or_else(|| rows.first().map(|(_, r)| r.len())).unwrap_or(0);
    let n = rows.iter().map(|(v, _)| v + 1).max().unwrap_or(0);
    let mut out = vec![0f32; n * dim];
    for (v, row) in rows {
        if row.len() != dim {
            bail!("node {v}: row width {} != dim {dim}", row.len());
        }
        out[v * dim..(v + 1) * dim].copy_from_slice(&row);
    }
    Ok((out, n, dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("kcore_embed_io_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_round_trip() {
        let g = generators::holme_kim(60, 2, 0.3, &mut crate::util::rng::Rng::new(1));
        let p = tmp("rt.edges");
        save_edge_list(&g, &p).unwrap();
        let g2 = load_edge_list(&p, Some(60)).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_parsing_rules() {
        let p = tmp("rules.edges");
        std::fs::write(&p, "# comment\n0 1\n\n2 2\n1 3\n").unwrap();
        let g = load_edge_list(&p, None).unwrap();
        assert_eq!(g.n_nodes(), 4);
        assert_eq!(g.n_edges(), 2); // self-loop 2-2 dropped
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_bad_input_errors() {
        let p = tmp("bad.edges");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edge_list(&p, None).is_err());
        std::fs::write(&p, "0\n").unwrap();
        assert!(load_edge_list(&p, None).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn embeddings_round_trip() {
        let (n, dim) = (5, 3);
        let emb: Vec<f32> = (0..n * dim).map(|i| i as f32 * 0.5).collect();
        let p = tmp("emb.tsv");
        save_embeddings(&emb, n, dim, &p).unwrap();
        let (back, n2, d2) = load_embeddings(&p).unwrap();
        assert_eq!((n2, d2), (n, dim));
        assert_eq!(back, emb);
        std::fs::remove_file(&p).unwrap();
    }
}
