//! Synthetic graph generators.
//!
//! The paper evaluates on Cora and two SNAP graphs; this testbed is
//! offline, so per DESIGN.md §Substitutions we generate *calibrated
//! stand-ins* that match each dataset's node/edge counts and — the part
//! that matters for degeneracy-based methods — the shape of its k-core
//! shell distribution:
//!
//! - [`cora_like`]: sparse, low-degeneracy citation-style graph;
//! - [`facebook_like`]: dense ego-net-style graph with planted dense
//!   communities producing the high-core "spikes" of §3.1.1 (including
//!   two far-apart dense blobs so high cores can disconnect, Fig 6);
//! - [`github_like`]: larger power-law graph with a "regular" smoothly
//!   decreasing shell profile.
//!
//! Plus the classic families (ER, BA, Holme-Kim, Watts-Strogatz, SBM)
//! used by tests, examples and ablations.

use std::collections::HashSet;

use super::csr::Graph;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Deterministic small graphs (tests + docs)
// ---------------------------------------------------------------------------

/// Cycle over n nodes (n >= 3).
pub fn ring(n: usize) -> Graph {
    assert!(n >= 3);
    let edges: Vec<(u32, u32)> = (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect();
    Graph::from_edges(n, &edges)
}

/// Path graph 0-1-...-n-1.
pub fn path(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..n as u32).map(|i| (i - 1, i)).collect();
    Graph::from_edges(n, &edges)
}

/// Complete graph K_n.
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            edges.push((i, j));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Star with `n-1` leaves around node 0.
pub fn star(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..n as u32).map(|i| (0, i)).collect();
    Graph::from_edges(n, &edges)
}

// ---------------------------------------------------------------------------
// Classic random families
// ---------------------------------------------------------------------------

/// G(n, m): exactly `m` distinct edges chosen uniformly.
pub fn erdos_renyi_gnm(n: usize, m: usize, rng: &mut Rng) -> Graph {
    let max_m = n * (n - 1) / 2;
    assert!(m <= max_m, "requested {m} edges > max {max_m}");
    let mut set = HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let a = rng.gen_index(n) as u32;
        let b = rng.gen_index(n) as u32;
        if a == b {
            continue;
        }
        let e = (a.min(b), a.max(b));
        if set.insert(e) {
            edges.push(e);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Barabási–Albert preferential attachment: each new node attaches to
/// `m` existing nodes with probability proportional to degree.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Rng) -> Graph {
    assert!(n > m && m >= 1);
    // "Repeated nodes" implementation: the targets list holds every edge
    // endpoint, so uniform sampling from it is degree-proportional.
    let mut repeated: Vec<u32> = Vec::with_capacity(2 * n * m);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m);
    // Seed clique over the first m+1 nodes keeps early attachment sane.
    for i in 0..=(m as u32) {
        for j in (i + 1)..=(m as u32) {
            edges.push((i, j));
            repeated.push(i);
            repeated.push(j);
        }
    }
    for v in (m as u32 + 1)..(n as u32) {
        let mut chosen = HashSet::with_capacity(m);
        while chosen.len() < m {
            let t = *rng.choose(&repeated);
            chosen.insert(t);
        }
        for &t in &chosen {
            edges.push((v, t));
            repeated.push(v);
            repeated.push(t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Holme–Kim "power-law cluster" model: BA attachment where each extra
/// link follows a triad-formation step with probability `p_triad`,
/// raising clustering (and degeneracy) above plain BA.
pub fn holme_kim(n: usize, m: usize, p_triad: f64, rng: &mut Rng) -> Graph {
    assert!(n > m && m >= 1);
    let mut repeated: Vec<u32> = Vec::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let add_edge = |edges: &mut Vec<(u32, u32)>,
                        repeated: &mut Vec<u32>,
                        adj: &mut Vec<Vec<u32>>,
                        a: u32,
                        b: u32|
     -> bool {
        if a == b || adj[a as usize].contains(&b) {
            return false;
        }
        edges.push((a, b));
        repeated.push(a);
        repeated.push(b);
        adj[a as usize].push(b);
        adj[b as usize].push(a);
        true
    };
    for i in 0..=(m as u32) {
        for j in (i + 1)..=(m as u32) {
            add_edge(&mut edges, &mut repeated, &mut adj, i, j);
        }
    }
    for v in (m as u32 + 1)..(n as u32) {
        let mut last_target: Option<u32> = None;
        let mut added = 0;
        let mut guard = 0;
        while added < m && guard < 50 * m {
            guard += 1;
            let use_triad = last_target.is_some() && rng.gen_f64() < p_triad;
            let t = if use_triad {
                let lt = last_target.unwrap();
                let nbrs = &adj[lt as usize];
                if nbrs.is_empty() {
                    *rng.choose(&repeated)
                } else {
                    *rng.choose(nbrs)
                }
            } else {
                *rng.choose(&repeated)
            };
            if add_edge(&mut edges, &mut repeated, &mut adj, v, t) {
                last_target = Some(t);
                added += 1;
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Watts–Strogatz small world: ring lattice with `k` neighbours per side
/// rewired with probability `beta`.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, rng: &mut Rng) -> Graph {
    assert!(k >= 1 && n > 2 * k);
    let mut set: HashSet<(u32, u32)> = HashSet::new();
    for i in 0..n as u32 {
        for d in 1..=k as u32 {
            let j = (i + d) % n as u32;
            set.insert((i.min(j), i.max(j)));
        }
    }
    let lattice: Vec<(u32, u32)> = set.iter().copied().collect();
    for &(a, b) in &lattice {
        if rng.gen_f64() < beta {
            // Rewire the far endpoint.
            let mut tries = 0;
            loop {
                tries += 1;
                if tries > 100 {
                    break;
                }
                let c = rng.gen_index(n) as u32;
                if c == a || c == b {
                    continue;
                }
                let e = (a.min(c), a.max(c));
                if !set.contains(&e) {
                    set.remove(&(a.min(b), a.max(b)));
                    set.insert(e);
                    break;
                }
            }
        }
    }
    let edges: Vec<(u32, u32)> = set.into_iter().collect();
    Graph::from_edges(n, &edges)
}

/// Stochastic block model. Returns the graph and each node's block label
/// (used by the node-classification extension task).
pub fn stochastic_block_model(
    sizes: &[usize],
    p_in: f64,
    p_out: f64,
    rng: &mut Rng,
) -> (Graph, Vec<u32>) {
    let n: usize = sizes.iter().sum();
    let mut labels = Vec::with_capacity(n);
    for (b, &s) in sizes.iter().enumerate() {
        labels.extend(std::iter::repeat(b as u32).take(s));
    }
    let mut edges = Vec::new();
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            let p = if labels[i as usize] == labels[j as usize] {
                p_in
            } else {
                p_out
            };
            if rng.gen_bool(p) {
                edges.push((i, j));
            }
        }
    }
    (Graph::from_edges(n, &edges), labels)
}

// ---------------------------------------------------------------------------
// Composition helpers
// ---------------------------------------------------------------------------

/// Add ER(p) edges among `nodes` on top of `base_edges` (dedup happens at
/// CSR build). Used to plant dense communities / high cores.
pub fn overlay_dense(
    edges: &mut Vec<(u32, u32)>,
    nodes: &[u32],
    p: f64,
    rng: &mut Rng,
) {
    for (i, &a) in nodes.iter().enumerate() {
        for &b in &nodes[i + 1..] {
            if rng.gen_bool(p) {
                edges.push((a.min(b), a.max(b)));
            }
        }
    }
}

/// Nudge a graph to exactly `target_m` edges by adding uniform random
/// non-edges or removing uniform random edges (best effort on removal:
/// degree-1 endpoints are skipped to avoid stranding nodes).
pub fn adjust_edge_count(g: &Graph, target_m: usize, rng: &mut Rng) -> Graph {
    let n = g.n_nodes();
    let mut edges: Vec<(u32, u32)> = g.edges().collect();
    if edges.len() < target_m {
        let mut set: HashSet<(u32, u32)> = edges.iter().copied().collect();
        while edges.len() < target_m {
            let a = rng.gen_index(n) as u32;
            let b = rng.gen_index(n) as u32;
            if a == b {
                continue;
            }
            let e = (a.min(b), a.max(b));
            if set.insert(e) {
                edges.push(e);
            }
        }
        Graph::from_edges(n, &edges)
    } else if edges.len() > target_m {
        let mut deg: Vec<u32> = (0..n as u32).map(|v| g.degree(v) as u32).collect();
        let mut keep = vec![true; edges.len()];
        let mut to_remove = edges.len() - target_m;
        let mut guard = 0usize;
        while to_remove > 0 && guard < edges.len() * 20 {
            guard += 1;
            let i = rng.gen_index(edges.len());
            let (a, b) = edges[i];
            if keep[i] && deg[a as usize] > 1 && deg[b as usize] > 1 {
                keep[i] = false;
                deg[a as usize] -= 1;
                deg[b as usize] -= 1;
                to_remove -= 1;
            }
        }
        let kept: Vec<(u32, u32)> = edges
            .into_iter()
            .zip(keep)
            .filter_map(|(e, k)| k.then_some(e))
            .collect();
        Graph::from_edges(n, &kept)
    } else {
        g.clone()
    }
}

// ---------------------------------------------------------------------------
// Calibrated stand-ins for the paper's datasets
// ---------------------------------------------------------------------------

/// Cora stand-in: 2708 nodes / 5429 edges, sparse citation-style,
/// low degeneracy (~3-4) — matches the paper's description of an
/// "erratic" shallow core structure.
pub fn cora_like(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let g = holme_kim(2708, 2, 0.35, &mut rng);
    adjust_edge_count(&g, 5429, &mut rng)
}

/// ego-Facebook stand-in: 4039 nodes / 88234 edges, dense with planted
/// communities creating the spiky high-core shells of §3.1.1, including
/// two far-apart very dense blobs so that high k-cores are disconnected
/// (the Fig 6 scenario). Degeneracy lands around ~100 (paper's is 115).
pub fn facebook_like(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let n = 4039usize;
    let target_m = 88234usize;
    // Sparse-ish preferential backbone: ~32k edges.
    let backbone = holme_kim(n, 8, 0.4, &mut rng);
    let mut edges: Vec<(u32, u32)> = backbone.edges().collect();

    // Two disjoint dense "ego circles" — these produce the top cores and
    // must be able to disconnect from each other at high k (Fig 6), so
    // like real ego circles they share NO direct edges: any backbone edge
    // crossing the two ranges is severed below. Ranges sit away from the
    // early preferential-attachment hubs.
    let blob_a_range = 1400u32..1550;
    let blob_b_range = (n as u32 - 150)..n as u32;
    let blob_a: Vec<u32> = blob_a_range.clone().collect();
    let blob_b: Vec<u32> = blob_b_range.clone().collect();
    overlay_dense(&mut edges, &blob_a, 0.82, &mut rng);
    overlay_dense(&mut edges, &blob_b, 0.78, &mut rng);

    // Mid-density communities over localized id ranges (ego circles).
    let mut cursor = 0u32;
    for i in 0..11 {
        let size = 90 + (i * 13) % 80; // 90..170
        let start = cursor % (n as u32 - 200);
        let nodes: Vec<u32> = (start..start + size as u32).collect();
        let p = 0.25 + 0.04 * (i % 5) as f64;
        overlay_dense(&mut edges, &nodes, p, &mut rng);
        cursor += 310;
    }

    let crosses = |a: u32, b: u32| -> bool {
        (blob_a_range.contains(&a) && blob_b_range.contains(&b))
            || (blob_a_range.contains(&b) && blob_b_range.contains(&a))
    };
    edges.retain(|&(a, b)| !crosses(a, b));

    // Hit the exact paper edge count without ever bridging the blobs.
    let g = Graph::from_edges(n, &edges);
    let mut edges: Vec<(u32, u32)> = g.edges().collect();
    if edges.len() < target_m {
        let mut set: HashSet<(u32, u32)> = edges.iter().copied().collect();
        while edges.len() < target_m {
            let a = rng.gen_index(n) as u32;
            let b = rng.gen_index(n) as u32;
            if a == b || crosses(a, b) {
                continue;
            }
            let e = (a.min(b), a.max(b));
            if set.insert(e) {
                edges.push(e);
            }
        }
        Graph::from_edges(n, &edges)
    } else {
        adjust_edge_count(&g, target_m, &mut rng)
    }
}

/// musae-Github stand-in: 37700 nodes / 289003 edges, power-law with a
/// single moderate dense core; "regular" smoothly decreasing shell
/// profile, degeneracy ~30-35 (paper's is 34).
pub fn github_like(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let n = 37700usize;
    let backbone = barabasi_albert(n, 6, &mut rng);
    let mut edges: Vec<(u32, u32)> = backbone.edges().collect();
    // One moderately dense hub community (machine-learning org cluster…).
    let hub: Vec<u32> = (0..130u32).collect();
    overlay_dense(&mut edges, &hub, 0.28, &mut rng);
    // A few medium communities to thicken the mid cores.
    for i in 0..8u32 {
        let start = 500 + i * 2200;
        let nodes: Vec<u32> = (start..start + 260).collect();
        overlay_dense(&mut edges, &nodes, 0.08, &mut rng);
    }
    let g = Graph::from_edges(n, &edges);
    adjust_edge_count(&g, 289_003, &mut rng)
}

/// Named dataset lookup used by the CLI and bench harness.
pub fn by_name(name: &str, seed: u64) -> Option<Graph> {
    match name {
        "cora" | "cora_like" => Some(cora_like(seed)),
        "facebook" | "facebook_like" => Some(facebook_like(seed)),
        "github" | "github_like" => Some(github_like(seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::connectivity::{is_connected, largest_component};

    #[test]
    fn deterministic_small_graphs() {
        assert_eq!(ring(5).n_edges(), 5);
        assert_eq!(path(5).n_edges(), 4);
        assert_eq!(complete(6).n_edges(), 15);
        assert_eq!(star(7).n_edges(), 6);
        assert_eq!(star(7).degree(0), 6);
    }

    #[test]
    fn gnm_has_exact_edges() {
        let mut rng = Rng::new(1);
        let g = erdos_renyi_gnm(100, 250, &mut rng);
        assert_eq!(g.n_nodes(), 100);
        assert_eq!(g.n_edges(), 250);
    }

    #[test]
    fn ba_heavy_tail_and_connected() {
        let mut rng = Rng::new(2);
        let g = barabasi_albert(2000, 3, &mut rng);
        assert!(is_connected(&g));
        // m(n-m-1) + seed clique edges, minus occasional dedup.
        assert!(g.n_edges() >= 3 * (2000 - 4) && g.n_edges() <= 3 * 2000 + 6);
        assert!(
            g.max_degree() as f64 > 8.0 * g.avg_degree(),
            "expected a hub: max={} avg={}",
            g.max_degree(),
            g.avg_degree()
        );
    }

    #[test]
    fn holme_kim_triads_raise_clustering() {
        let mut rng = Rng::new(3);
        let hk = holme_kim(1500, 3, 0.9, &mut rng);
        let mut rng2 = Rng::new(3);
        let ba = barabasi_albert(1500, 3, &mut rng2);
        let c_hk = crate::graph::metrics::global_clustering(&hk, 20_000, &mut Rng::new(9));
        let c_ba = crate::graph::metrics::global_clustering(&ba, 20_000, &mut Rng::new(9));
        assert!(
            c_hk > 1.5 * c_ba,
            "holme-kim clustering {c_hk} not above BA {c_ba}"
        );
    }

    #[test]
    fn watts_strogatz_degree_preserved_roughly() {
        let mut rng = Rng::new(4);
        let g = watts_strogatz(400, 3, 0.1, &mut rng);
        assert_eq!(g.n_edges(), 1200);
        assert!((g.avg_degree() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn sbm_labels_and_assortativity() {
        let mut rng = Rng::new(5);
        let (g, labels) = stochastic_block_model(&[50, 50, 50], 0.3, 0.01, &mut rng);
        assert_eq!(g.n_nodes(), 150);
        assert_eq!(labels.len(), 150);
        // Count in-block vs out-block edges.
        let (mut within, mut across) = (0, 0);
        for (u, v) in g.edges() {
            if labels[u as usize] == labels[v as usize] {
                within += 1;
            } else {
                across += 1;
            }
        }
        assert!(within > 4 * across, "within={within} across={across}");
    }

    #[test]
    fn adjust_edge_count_exact() {
        let mut rng = Rng::new(6);
        let g = erdos_renyi_gnm(200, 400, &mut rng);
        let up = adjust_edge_count(&g, 500, &mut rng);
        assert_eq!(up.n_edges(), 500);
        let down = adjust_edge_count(&g, 300, &mut rng);
        assert_eq!(down.n_edges(), 300);
        let same = adjust_edge_count(&g, 400, &mut rng);
        assert_eq!(same.n_edges(), 400);
        // Removal never strands nodes that had degree >= 1... unless forced.
        for v in 0..down.n_nodes() as u32 {
            if g.degree(v) > 0 {
                assert!(down.degree(v) >= 1, "node {v} stranded");
            }
        }
    }

    #[test]
    fn calibrated_sizes_match_paper() {
        let cora = cora_like(11);
        assert_eq!(cora.n_nodes(), 2708);
        assert_eq!(cora.n_edges(), 5429);

        let fb = facebook_like(11);
        assert_eq!(fb.n_nodes(), 4039);
        assert_eq!(fb.n_edges(), 88234);
        // Most of the graph is one component.
        assert!(largest_component(&fb).len() > 3800);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("cora", 1).is_some());
        assert!(by_name("facebook_like", 1).is_some());
        assert!(by_name("nope", 1).is_none());
    }
}
