//! Graph substrate: CSR storage, connectivity, generators, metrics, I/O.
//!
//! The paper's pipeline operates on undirected unweighted graphs
//! (§3.1.1); everything downstream (k-core decomposition, walks,
//! propagation, evaluation) consumes [`csr::Graph`].

pub mod connectivity;
pub mod csr;
pub mod generators;
pub mod io;
pub mod metrics;

pub use csr::Graph;
