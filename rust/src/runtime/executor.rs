//! PJRT execution sessions for the AOT artifacts.
//!
//! [`SgnsSession`] owns the training state as a **device-resident**
//! buffer: each `step` uploads only the (small) batch tensor and chains
//! the state through `execute_b`, so the `[2V+2, D]` weight matrix never
//! crosses the host boundary between steps (see DESIGN.md §Runtime).
//! [`PropSession`] does the same for Jacobi mean-propagation rounds.

use anyhow::{anyhow, Context, Result};

use super::artifact::{Manifest, PropMeta, SgnsMeta};

/// Shared PJRT CPU client. One per process; sessions borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, manifest: &Manifest, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = manifest.hlo_path(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {}", path.display()))
    }

    /// Compile the SGNS step for `meta` and return a fresh session.
    pub fn sgns_session(&self, manifest: &Manifest, meta: &SgnsMeta) -> Result<SgnsSession<'_>> {
        let exe = self.compile(manifest, &meta.file)?;
        Ok(SgnsSession {
            client: &self.client,
            exe,
            meta: meta.clone(),
            state: None,
            steps: 0,
        })
    }

    /// Compile the propagation step for `meta` and return a session.
    pub fn prop_session(&self, manifest: &Manifest, meta: &PropMeta) -> Result<PropSession<'_>> {
        let exe = self.compile(manifest, &meta.file)?;
        Ok(PropSession {
            client: &self.client,
            exe,
            meta: meta.clone(),
            state: None,
        })
    }
}

/// Device-resident SGNS training session.
pub struct SgnsSession<'rt> {
    client: &'rt xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    meta: SgnsMeta,
    state: Option<xla::PjRtBuffer>,
    steps: u64,
}

impl<'rt> SgnsSession<'rt> {
    pub fn meta(&self) -> &SgnsMeta {
        &self.meta
    }

    /// Upload the initial state. `w_in`/`w_out` are `n x dim` with
    /// `n <= vocab`; rows `n..vocab` are padding the step never touches.
    pub fn start(&mut self, n: usize, w_in: &[f32], w_out: &[f32]) -> Result<()> {
        let (v, d) = (self.meta.vocab, self.meta.dim);
        assert!(n <= v, "{n} nodes exceed artifact vocab {v}");
        assert_eq!(w_in.len(), n * d);
        assert_eq!(w_out.len(), n * d);
        let rows = self.meta.state_rows();
        let mut state = vec![0f32; rows * d];
        state[..n * d].copy_from_slice(w_in);
        state[v * d..v * d + n * d].copy_from_slice(w_out);
        let buf = self
            .client
            .buffer_from_host_buffer(&state, &[rows, d], None)
            .map_err(|e| anyhow!("uploading state: {e}"))?;
        self.state = Some(buf);
        self.steps = 0;
        Ok(())
    }

    /// Run one super-batch (`scan_steps` micro-steps) on device. `idx` is
    /// the `[S, B, 3+K]` i32 tensor, `lr` the per-micro-step rates.
    pub fn step(&mut self, idx: &[i32], lr: &[f32]) -> Result<()> {
        let m = &self.meta;
        assert_eq!(idx.len(), m.scan_steps * m.batch * m.lane(), "batch shape");
        assert_eq!(lr.len(), m.scan_steps);
        let state = self
            .state
            .take()
            .ok_or_else(|| anyhow!("step() before start()"))?;
        let idx_buf = self
            .client
            .buffer_from_host_buffer(idx, &[m.scan_steps, m.batch, m.lane()], None)
            .map_err(|e| anyhow!("uploading batch: {e}"))?;
        let lr_buf = self
            .client
            .buffer_from_host_buffer(lr, &[m.scan_steps], None)
            .map_err(|e| anyhow!("uploading lr: {e}"))?;
        let outs = self
            .exe
            .execute_b(&[&state, &idx_buf, &lr_buf])
            .map_err(|e| anyhow!("executing sgns step: {e}"))?;
        let new_state = outs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("sgns step returned no buffer"))?;
        self.state = Some(new_state);
        self.steps += 1;
        Ok(())
    }

    pub fn steps_run(&self) -> u64 {
        self.steps
    }

    /// Download the full state (blocking). Returns
    /// (w_in `n x d`, w_out `n x d`, loss_sum, pair_count).
    pub fn read_state(&self, n: usize) -> Result<(Vec<f32>, Vec<f32>, f64, f64)> {
        let (v, d) = (self.meta.vocab, self.meta.dim);
        let state = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow!("read_state() before start()"))?;
        let lit = state
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading state: {e}"))?;
        let flat: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e}"))?;
        assert_eq!(flat.len(), self.meta.state_rows() * d);
        let w_in = flat[..n * d].to_vec();
        let w_out = flat[v * d..v * d + n * d].to_vec();
        let stats = &flat[2 * v * d..2 * v * d + d];
        Ok((w_in, w_out, stats[0] as f64, stats[1] as f64))
    }
}

/// Device-resident mean-propagation session.
pub struct PropSession<'rt> {
    client: &'rt xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    meta: PropMeta,
    state: Option<xla::PjRtBuffer>,
}

/// One frontier's padded index tensors, reusable across Jacobi rounds.
pub struct FrontierBuffers {
    rows: xla::PjRtBuffer,
    nbrs: xla::PjRtBuffer,
    mask: xla::PjRtBuffer,
}

impl<'rt> PropSession<'rt> {
    pub fn meta(&self) -> &PropMeta {
        &self.meta
    }

    /// Upload the `n x dim` embedding state (rows `n..vocab` padding).
    pub fn start(&mut self, n: usize, emb: &[f32]) -> Result<()> {
        let (v, d) = (self.meta.vocab, self.meta.dim);
        assert!(n <= v);
        assert_eq!(emb.len(), n * d);
        let mut state = vec![0f32; v * d];
        state[..n * d].copy_from_slice(emb);
        self.state = Some(
            self.client
                .buffer_from_host_buffer(&state, &[v, d], None)
                .map_err(|e| anyhow!("uploading prop state: {e}"))?,
        );
        Ok(())
    }

    /// Upload a frontier: `rows[i]` is overwritten with the masked mean
    /// of `nbrs[i, :]`. Padding lanes must point at a scratch row with an
    /// all-zero mask.
    pub fn upload_frontier(
        &self,
        rows: &[i32],
        nbrs: &[i32],
        mask: &[f32],
    ) -> Result<FrontierBuffers> {
        let (f, m) = (self.meta.frontier, self.meta.max_deg);
        assert_eq!(rows.len(), f);
        assert_eq!(nbrs.len(), f * m);
        assert_eq!(mask.len(), f * m);
        Ok(FrontierBuffers {
            rows: self
                .client
                .buffer_from_host_buffer(rows, &[f], None)
                .map_err(|e| anyhow!("uploading rows: {e}"))?,
            nbrs: self
                .client
                .buffer_from_host_buffer(nbrs, &[f, m], None)
                .map_err(|e| anyhow!("uploading nbrs: {e}"))?,
            mask: self
                .client
                .buffer_from_host_buffer(mask, &[f, m], None)
                .map_err(|e| anyhow!("uploading mask: {e}"))?,
        })
    }

    /// One Jacobi round over an uploaded frontier.
    pub fn step(&mut self, frontier: &FrontierBuffers) -> Result<()> {
        let state = self
            .state
            .take()
            .ok_or_else(|| anyhow!("step() before start()"))?;
        let outs = self
            .exe
            .execute_b(&[&state, &frontier.rows, &frontier.nbrs, &frontier.mask])
            .map_err(|e| anyhow!("executing prop step: {e}"))?;
        self.state = Some(
            outs.into_iter()
                .next()
                .and_then(|r| r.into_iter().next())
                .ok_or_else(|| anyhow!("prop step returned no buffer"))?,
        );
        Ok(())
    }

    /// Download the embedding rows `0..n`.
    pub fn read_state(&self, n: usize) -> Result<Vec<f32>> {
        let d = self.meta.dim;
        let state = self
            .state
            .as_ref()
            .ok_or_else(|| anyhow!("read_state() before start()"))?;
        let lit = state
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading prop state: {e}"))?;
        let flat: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e}"))?;
        Ok(flat[..n * d].to_vec())
    }
}
