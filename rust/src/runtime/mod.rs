//! PJRT runtime: load the AOT HLO artifacts (`make artifacts`) and run
//! them from rust with device-resident state. Python never runs here.

pub mod artifact;
pub mod executor;

pub use artifact::{default_artifacts_dir, Manifest, PropMeta, SgnsMeta};
pub use executor::{PropSession, Runtime, SgnsSession};
