//! Artifact manifest: metadata for the AOT-compiled HLO programs emitted
//! by `python/compile/aot.py` (`make artifacts`).
//!
//! The rust side trusts `manifest.json` for every static shape; artifact
//! selection picks the smallest configuration that fits a graph.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// SGNS train-step artifact parameters (python: model.make_sgns_step).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SgnsMeta {
    pub name: String,
    pub file: String,
    pub vocab: usize,
    pub dim: usize,
    pub batch: usize,
    pub negatives: usize,
    pub scan_steps: usize,
}

impl SgnsMeta {
    /// State tensor rows: `2*vocab + 2` (W_in, W_out, stats, scratch).
    pub fn state_rows(&self) -> usize {
        2 * self.vocab + 2
    }

    /// Pairs consumed per PJRT dispatch.
    pub fn pairs_per_call(&self) -> usize {
        self.batch * self.scan_steps
    }

    /// i32 lane width: [valid, center, context, negs...].
    pub fn lane(&self) -> usize {
        3 + self.negatives
    }
}

/// Mean-propagation step artifact parameters (python: model.make_prop_step).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PropMeta {
    pub name: String,
    pub file: String,
    pub vocab: usize,
    pub dim: usize,
    pub frontier: usize,
    pub max_deg: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub sgns: Vec<SgnsMeta>,
    pub prop: Vec<PropMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing version"))?;
        if version != 1 {
            bail!("manifest: unsupported version {version}");
        }
        let arts = root
            .get("artifacts")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("manifest: missing artifacts array"))?;
        let mut sgns = Vec::new();
        let mut prop = Vec::new();
        for a in arts {
            let field = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("manifest: artifact missing field {k:?}"))
            };
            let s_field = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest: artifact missing field {k:?}"))?
                    .to_string())
            };
            match a.get("kind").and_then(Json::as_str) {
                Some("sgns") => sgns.push(SgnsMeta {
                    name: s_field("name")?,
                    file: s_field("file")?,
                    vocab: field("vocab")?,
                    dim: field("dim")?,
                    batch: field("batch")?,
                    negatives: field("negatives")?,
                    scan_steps: field("scan_steps")?,
                }),
                Some("prop") => prop.push(PropMeta {
                    name: s_field("name")?,
                    file: s_field("file")?,
                    vocab: field("vocab")?,
                    dim: field("dim")?,
                    frontier: field("frontier")?,
                    max_deg: field("max_deg")?,
                }),
                Some(k) => bail!("manifest: unknown artifact kind {k:?}"),
                None => bail!("manifest: artifact missing kind"),
            }
        }
        sgns.sort_by_key(|m| m.vocab);
        prop.sort_by_key(|m| m.vocab);
        Ok(Manifest {
            dir: dir.to_path_buf(),
            sgns,
            prop,
        })
    }

    /// Smallest SGNS artifact whose vocab fits `n_nodes`.
    pub fn select_sgns(&self, n_nodes: usize) -> Result<&SgnsMeta> {
        self.sgns
            .iter()
            .find(|m| m.vocab >= n_nodes)
            .ok_or_else(|| {
                anyhow!(
                    "no SGNS artifact fits {n_nodes} nodes (max vocab {})",
                    self.sgns.last().map(|m| m.vocab).unwrap_or(0)
                )
            })
    }

    /// Smallest prop artifact whose vocab fits `n_nodes`.
    pub fn select_prop(&self, n_nodes: usize) -> Result<&PropMeta> {
        self.prop
            .iter()
            .find(|m| m.vocab >= n_nodes)
            .ok_or_else(|| {
                anyhow!(
                    "no prop artifact fits {n_nodes} nodes (max vocab {})",
                    self.prop.last().map(|m| m.vocab).unwrap_or(0)
                )
            })
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// Default artifacts directory: `$KCORE_EMBED_ARTIFACTS` or `artifacts/`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("KCORE_EMBED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "sgns_v4096", "kind": "sgns", "file": "sgns_v4096.hlo.txt",
         "vocab": 4096, "dim": 128, "batch": 512, "negatives": 5,
         "scan_steps": 16, "block_b": 128},
        {"name": "sgns_v1024", "kind": "sgns", "file": "sgns_v1024.hlo.txt",
         "vocab": 1024, "dim": 128, "batch": 256, "negatives": 5,
         "scan_steps": 16, "block_b": 64},
        {"name": "prop_v1024", "kind": "prop", "file": "prop_v1024.hlo.txt",
         "vocab": 1024, "dim": 128, "frontier": 256, "max_deg": 32,
         "block_f": 64}
      ]
    }"#;

    #[test]
    fn parse_and_select() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.sgns.len(), 2);
        assert_eq!(m.prop.len(), 1);
        // Sorted by vocab; selection picks the smallest fit.
        assert_eq!(m.select_sgns(1000).unwrap().name, "sgns_v1024");
        assert_eq!(m.select_sgns(1024).unwrap().name, "sgns_v1024");
        assert_eq!(m.select_sgns(1025).unwrap().name, "sgns_v4096");
        assert!(m.select_sgns(100_000).is_err());
        assert_eq!(m.select_prop(500).unwrap().name, "prop_v1024");
        assert!(m.select_prop(5000).is_err());
    }

    #[test]
    fn derived_quantities() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let s = m.select_sgns(4000).unwrap();
        assert_eq!(s.state_rows(), 8194);
        assert_eq!(s.pairs_per_call(), 512 * 16);
        assert_eq!(s.lane(), 8);
        assert_eq!(
            m.hlo_path(&s.file),
            Path::new("/tmp/a").join("sgns_v4096.hlo.txt")
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("."), "{}").is_err());
        assert!(Manifest::parse(Path::new("."), "{\"version\": 2, \"artifacts\": []}").is_err());
        let bad_kind = r#"{"version":1,"artifacts":[{"kind":"x","name":"a","file":"f"}]}"#;
        assert!(Manifest::parse(Path::new("."), bad_kind).is_err());
        let missing = r#"{"version":1,"artifacts":[{"kind":"sgns","name":"a","file":"f"}]}"#;
        assert!(Manifest::parse(Path::new("."), missing).is_err());
    }
}
