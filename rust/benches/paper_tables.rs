//! `cargo bench` target: quick-mode regeneration of every paper table
//! and figure (reduced trials/walks so the suite completes in minutes;
//! the full-scale runs go through `kcore-embed bench --exp <name>`).
//!
//! harness = false: this is an end-to-end experiment driver, not a
//! statistical micro-benchmark.

use std::time::Instant;

use kcore_embed::coordinator::bench::{run_bench, BenchOpts};

fn main() {
    let mut opts = BenchOpts::quick();
    opts.out_dir = std::path::PathBuf::from("bench_out/quick");
    // Allow narrowing to one experiment: `cargo bench --bench paper_tables -- table2`
    let only: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let names: Vec<&str> = if only.is_empty() {
        vec![
            "coredist", "fig1", "table1", "table6", "table2", "table3", "table8", "table4",
            "table10", "fig2", "fig3", "fig4", "fig5", "fig6",
        ]
    } else {
        only.iter().map(|s| s.as_str()).collect()
    };
    println!("paper-table bench (quick mode: {} trials, n = {} walks/node)\n", opts.trials, opts.walks_per_node);
    let mut failures = 0;
    for name in names {
        let t0 = Instant::now();
        match run_bench(name, &opts, None) {
            Ok(out) => {
                println!("==== {name} ({:.1}s) ====", t0.elapsed().as_secs_f64());
                println!("{out}");
            }
            Err(e) => {
                failures += 1;
                eprintln!("==== {name} FAILED: {e:#} ====");
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
