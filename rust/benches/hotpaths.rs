//! `cargo bench` target: micro-benchmarks of the per-layer hot paths the
//! §Perf pass optimizes. Reports throughput per component so regressions
//! are visible without running whole experiments.
//!
//! harness = false (hand-rolled timing: warmup + repeated runs, report
//! best and mean — criterion is unavailable offline). Each bench also
//! prints a single-line JSON twin of its human-readable line (the
//! bench-harness idiom: one JSON object per line, greppable from logs).
//!
//! Args (after `cargo bench --bench hotpaths --`):
//!   --train-only   run only the SGNS trainer benches
//!   --quick        smoke profile (small corpus, one timed iter) for CI
//!   --json PATH    write the train-bench summary object to PATH
//!                  (`make bench-train` writes BENCH_train.json)
//!
//! The train section benches the fused-kernel trainers against the
//! pre-kernel baselines kept verbatim below (scalar serial; per-element
//! atomic hogwild), so the speedups recorded in BENCH_train.json are
//! measured against real code, not a guess (DESIGN.md §Training).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering::Relaxed};
use std::time::Instant;

use kcore_embed::cores::core_decomposition;
use kcore_embed::embed::kernels::{self, SigmoidTable};
use kcore_embed::embed::{batches::SgnsParams, native, sampler::NegativeSampler, Embedding};
use kcore_embed::eval::logistic::{LogRegParams, LogisticRegression};
use kcore_embed::graph::generators;
use kcore_embed::propagate::{propagate_mean, PropagationParams};
use kcore_embed::runtime::{default_artifacts_dir, Manifest, Runtime};
use kcore_embed::util::json::Json;
use kcore_embed::util::pool;
use kcore_embed::util::rng::Rng;
use kcore_embed::walks::{
    generate_node2vec_shards, generate_node2vec_walks, generate_walk_shards, generate_walks,
    Corpus, Node2VecParams, PairStream, ShardOpts, ShardedCorpus, WalkParams, WalkSchedule,
};

struct Opts {
    train_only: bool,
    quick: bool,
    json_path: Option<String>,
}

fn parse_opts() -> Opts {
    let mut o = Opts {
        train_only: false,
        quick: false,
        json_path: None,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--train-only" => o.train_only = true,
            "--quick" => o.quick = true,
            "--json" => o.json_path = args.next(),
            // cargo bench passes --bench through to harness=false bins.
            "--bench" => {}
            x => eprintln!("(ignoring unknown arg {x})"),
        }
    }
    o
}

struct BenchEntry {
    name: &'static str,
    unit: &'static str,
    best_per_s: f64,
    mean_per_s: f64,
    work: u64,
}

fn bench_json(e: &BenchEntry) -> String {
    Json::object(vec![
        ("bench", Json::str(e.name)),
        ("unit", Json::str(e.unit)),
        ("best_per_s", Json::num(e.best_per_s)),
        ("mean_per_s", Json::num(e.mean_per_s)),
        ("work_per_iter", Json::num(e.work as f64)),
    ])
    .to_string()
}

fn bench<F: FnMut() -> u64>(
    name: &'static str,
    unit: &'static str,
    iters: usize,
    mut f: F,
) -> BenchEntry {
    // warmup
    let _ = f();
    let mut best = f64::INFINITY;
    let mut mean = 0.0;
    let mut work = 0u64;
    for _ in 0..iters {
        let t0 = Instant::now();
        work = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        mean += dt / iters as f64;
    }
    let entry = BenchEntry {
        name,
        unit,
        best_per_s: work as f64 / best,
        mean_per_s: work as f64 / mean,
        work,
    };
    println!(
        "{name:<42} best {:>9.2} {unit}/s   mean {:>9.2} {unit}/s   ({} {unit}/iter)",
        entry.best_per_s / 1e6,
        entry.mean_per_s / 1e6,
        work
    );
    println!("{}", bench_json(&entry));
    entry
}

fn main() {
    let opts = parse_opts();
    println!("hot-path micro-benchmarks (M = 1e6 units/s)\n");
    if !opts.train_only {
        bench_layers();
    }
    let summary = bench_train(&opts);
    println!("{summary}");
    if let Some(path) = &opts.json_path {
        std::fs::write(path, format!("{summary}\n")).expect("write train-bench json");
        println!("wrote {path}");
    }
}

// ---------------------------------------------------------------------------
// SGNS trainer benches: fused kernels vs the pre-kernel baselines.
// ---------------------------------------------------------------------------

/// Run the four trainer benches and return the single-object JSON
/// summary (`BENCH_train.json` schema): pairs/s for scalar-vs-fused
/// serial and atomic-vs-racy hogwild, plus the derived speedups.
fn bench_train(opts: &Opts) -> String {
    let (n_nodes, walks, walk_length, dim, iters) = if opts.quick {
        (300usize, 3u32, 12usize, 64usize, 1usize)
    } else {
        (1000, 8, 20, 128, 3)
    };
    let params = SgnsParams {
        dim,
        seed: 3,
        ..Default::default()
    };
    let g = generators::holme_kim(n_nodes, 4, 0.4, &mut Rng::new(3));
    let sched = WalkSchedule::uniform(n_nodes, walks);
    let wp = WalkParams {
        walk_length,
        seed: 3,
        threads: pool::default_threads(),
    };
    let corpus = generate_walks(&g, &sched, &wp);
    let sharded = generate_walk_shards(
        &g,
        &sched,
        &wp,
        &ShardOpts {
            shards: 16,
            ..Default::default()
        },
    );
    // At least 2 workers so the hogwild comparison measures the shared-
    // matrix representation, not the serial fallback.
    let threads = pool::default_threads().max(2);

    let serial_scalar = bench("SGNS serial scalar-ref (M pairs)", "M-pair", iters, || {
        let (loss, n) = train_serial_scalar_reference(&corpus, n_nodes, &params);
        std::hint::black_box(loss);
        n
    });
    let serial_fused = bench("SGNS serial fused (M pairs)", "M-pair", iters, || {
        let r = native::train_native(&corpus, n_nodes, &params);
        std::hint::black_box(r.mean_loss);
        r.n_pairs
    });
    let hog_atomic = bench("SGNS hogwild atomic-ref (M pairs)", "M-pair", iters, || {
        let (loss, n) = train_hogwild_atomic_reference(&sharded, n_nodes, &params, threads);
        std::hint::black_box(loss);
        n
    });
    let hog_racy = bench("SGNS hogwild racy fused (M pairs)", "M-pair", iters, || {
        let r = native::train_native_parallel_sharded(&sharded, n_nodes, &params, threads);
        std::hint::black_box(r.mean_loss);
        r.n_pairs
    });

    let serial_speedup = serial_fused.best_per_s / serial_scalar.best_per_s;
    let hogwild_speedup = hog_racy.best_per_s / hog_atomic.best_per_s;
    println!(
        "    train speedups: serial fused {serial_speedup:.2}x vs scalar, \
         hogwild racy {hogwild_speedup:.2}x vs atomic ({threads} threads)"
    );
    Json::object(vec![
        ("bench", Json::str("sgns_train")),
        ("quick", Json::Bool(opts.quick)),
        ("dim", Json::num(params.dim as f64)),
        ("negatives", Json::num(params.negatives as f64)),
        ("threads", Json::num(threads as f64)),
        ("serial_scalar_pairs_per_s", Json::num(serial_scalar.best_per_s)),
        ("serial_fused_pairs_per_s", Json::num(serial_fused.best_per_s)),
        ("serial_fused_speedup", Json::num(serial_speedup)),
        ("hogwild_atomic_pairs_per_s", Json::num(hog_atomic.best_per_s)),
        ("hogwild_racy_pairs_per_s", Json::num(hog_racy.best_per_s)),
        ("hogwild_racy_speedup", Json::num(hogwild_speedup)),
    ])
    .to_string()
}

// -- pre-kernel baselines, kept verbatim for the comparison ----------------

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// `acc += scale * row`
fn accumulate_scalar(acc: &mut [f32], row: &[f32], scale: f32) {
    for (a, &r) in acc.iter_mut().zip(row) {
        *a += scale * r;
    }
}

/// `row += scale * delta`
fn axpy_scalar(row: &mut [f32], delta: &[f32], scale: f32) {
    for (r, &d) in row.iter_mut().zip(delta) {
        *r += scale * d;
    }
}

/// The pre-kernel serial trainer: naive sequential dot plus separate
/// accumulate/axpy passes per target row (three traversals where the
/// fused path does two).
fn train_serial_scalar_reference(
    corpus: &Corpus,
    n_nodes: usize,
    params: &SgnsParams,
) -> (f64, u64) {
    let mut rng = Rng::new(params.seed);
    let mut w_in = Embedding::word2vec_init(n_nodes, params.dim, &mut rng);
    let mut w_out = Embedding::zeros(n_nodes, params.dim);
    let sampler = NegativeSampler::from_counts(&corpus.node_counts());
    let sig = SigmoidTable::new();
    let total_pairs = (corpus.exact_pair_count(params.window) * params.epochs as u64).max(1);
    let mut emitted = 0u64;
    let mut loss_sum = 0f64;
    let mut neg_buf: Vec<u32> = Vec::with_capacity(params.negatives);
    let mut grad_h = vec![0f32; params.dim];
    for epoch in 0..params.epochs {
        let mut neg_rng = Rng::new(params.seed ^ (0x5EED + epoch as u64));
        let pairs = PairStream::new(
            corpus,
            params.window,
            Rng::new(params.seed ^ (0x9A1C + epoch as u64)),
        );
        for (center, context) in pairs {
            let frac = emitted as f64 / total_pairs as f64;
            let lr = ((params.lr0 as f64 * (1.0 - frac)).max(params.lr_min as f64)) as f32;
            sampler.sample_k(params.negatives, context, &mut neg_rng, &mut neg_buf);
            grad_h.iter_mut().for_each(|x| *x = 0.0);
            let h = w_in.row(center);
            let pos = dot_scalar(h, w_out.row(context));
            let g_pos = sig.get(pos) - 1.0;
            loss_sum += -kernels::ln_sigmoid(pos) as f64;
            accumulate_scalar(&mut grad_h, w_out.row(context), g_pos);
            axpy_scalar(w_out.row_mut(context), h, -lr * g_pos);
            for &ng in &neg_buf {
                let neg = dot_scalar(h, w_out.row(ng));
                let s_neg = sig.get(neg);
                loss_sum += -kernels::ln_sigmoid(-neg) as f64;
                accumulate_scalar(&mut grad_h, w_out.row(ng), s_neg);
                axpy_scalar(w_out.row_mut(ng), h, -lr * s_neg);
            }
            axpy_scalar(w_in.row_mut(center), &grad_h, -lr);
            emitted += 1;
        }
    }
    (loss_sum, emitted)
}

#[inline]
fn at_load(a: &AtomicU32) -> f32 {
    f32::from_bits(a.load(Relaxed))
}

#[inline]
fn at_store(a: &AtomicU32, v: f32) {
    a.store(v.to_bits(), Relaxed)
}

/// The pre-kernel hogwild trainer: `Vec<AtomicU32>` matrices with
/// relaxed per-element load/store on every row pass, and the sigmoid
/// table rebuilt per shard task — the exact shape the racy fused
/// trainer replaced.
fn train_hogwild_atomic_reference(
    corpus: &ShardedCorpus,
    n_nodes: usize,
    params: &SgnsParams,
    threads: usize,
) -> (f64, u64) {
    let dim = params.dim;
    let mut seed_rng = Rng::new(params.seed);
    let init = Embedding::word2vec_init(n_nodes, dim, &mut seed_rng);
    let w_in: Vec<AtomicU32> = init
        .data()
        .iter()
        .map(|x| AtomicU32::new(x.to_bits()))
        .collect();
    let w_out: Vec<AtomicU32> = (0..n_nodes * dim).map(|_| AtomicU32::new(0)).collect();
    let sampler = NegativeSampler::from_counts(&corpus.node_counts());
    let total_pairs = (corpus.exact_pair_count(params.window) * params.epochs as u64).max(1);
    let global_pairs = AtomicU64::new(0);

    let results: Vec<(f64, u64)> = pool::parallel_tasks(corpus.n_shards(), threads, |si| {
        let shard = &corpus.shards()[si];
        let sig = SigmoidTable::new();
        let mut rng = Rng::new(params.seed ^ (0xBEEF + si as u64));
        let mut neg_buf: Vec<u32> = Vec::with_capacity(params.negatives);
        let mut grad_h = vec![0f32; dim];
        let mut h_snap = vec![0f32; dim];
        let mut walk: Vec<u32> = Vec::new();
        let mut loss_sum = 0f64;
        let mut local_pairs = 0u64;
        let mut lr = params.lr0;
        for _epoch in 0..params.epochs {
            let mut reader = shard.reader();
            while reader.next_walk(&mut walk) {
                for c_pos in 0..walk.len() {
                    let radius = 1 + rng.gen_index(params.window);
                    let lo = c_pos.saturating_sub(radius);
                    let hi = (c_pos + radius).min(walk.len() - 1);
                    for t_pos in lo..=hi {
                        if t_pos == c_pos {
                            continue;
                        }
                        let center = walk[c_pos] as usize;
                        let context = walk[t_pos] as usize;
                        if local_pairs % 4096 == 0 {
                            let done = global_pairs.fetch_add(4096, Relaxed);
                            let frac = done as f64 / total_pairs as f64;
                            lr = ((params.lr0 as f64 * (1.0 - frac))
                                .max(params.lr_min as f64))
                                as f32;
                        }
                        sampler.sample_k(params.negatives, context as u32, &mut rng, &mut neg_buf);
                        let h_row = &w_in[center * dim..(center + 1) * dim];
                        for (s, a) in h_snap.iter_mut().zip(h_row) {
                            *s = at_load(a);
                        }
                        grad_h.iter_mut().for_each(|x| *x = 0.0);
                        let c_row = &w_out[context * dim..(context + 1) * dim];
                        let mut pos = 0f32;
                        for (hs, ca) in h_snap.iter().zip(c_row) {
                            pos += hs * at_load(ca);
                        }
                        let g_pos = sig.get(pos) - 1.0;
                        loss_sum += -kernels::ln_sigmoid(pos) as f64;
                        for ((gh, ca), hs) in grad_h.iter_mut().zip(c_row).zip(&h_snap) {
                            *gh += g_pos * at_load(ca);
                            at_store(ca, at_load(ca) - lr * g_pos * hs);
                        }
                        for &ng in &neg_buf {
                            let n_row = &w_out[ng as usize * dim..(ng as usize + 1) * dim];
                            let mut neg = 0f32;
                            for (hs, na) in h_snap.iter().zip(n_row) {
                                neg += hs * at_load(na);
                            }
                            let s_neg = sig.get(neg);
                            loss_sum += -kernels::ln_sigmoid(-neg) as f64;
                            for ((gh, na), hs) in grad_h.iter_mut().zip(n_row).zip(&h_snap) {
                                *gh += s_neg * at_load(na);
                                at_store(na, at_load(na) - lr * s_neg * hs);
                            }
                        }
                        for (ha, gh) in h_row.iter().zip(&grad_h) {
                            at_store(ha, at_load(ha) - lr * gh);
                        }
                        local_pairs += 1;
                    }
                }
            }
        }
        (loss_sum, local_pairs)
    });

    let (loss_sum, n_pairs) = results
        .into_iter()
        .fold((0f64, 0u64), |(l, n), (dl, dn)| (l + dl, n + dn));
    std::hint::black_box(at_load(&w_in[0]) + at_load(&w_out[0]));
    (loss_sum, n_pairs)
}

// ---------------------------------------------------------------------------
// Per-layer benches (the original hotpaths list).
// ---------------------------------------------------------------------------

fn bench_layers() {
    let mut rng = Rng::new(1);
    let fb = generators::facebook_like(7);
    let gh = generators::github_like(7);

    // L3: core decomposition (unit: edges).
    bench("core_decomposition facebook (M edges)", "M-edge", 5, || {
        let d = core_decomposition(&fb);
        std::hint::black_box(d.degeneracy);
        fb.n_edges() as u64
    });
    bench("core_decomposition github (M edges)", "M-edge", 3, || {
        let d = core_decomposition(&gh);
        std::hint::black_box(d.degeneracy);
        gh.n_edges() as u64
    });

    // L3: walk generation (unit: walk steps).
    let sched = WalkSchedule::uniform(fb.n_nodes(), 5);
    bench("walk generation facebook (M steps)", "M-step", 3, || {
        let c = generate_walks(
            &fb,
            &sched,
            &WalkParams {
                walk_length: 30,
                seed: 2,
                threads: pool::default_threads(),
            },
        );
        c.n_tokens() as u64
    });

    // L3: negative sampling (unit: draws).
    let counts: Vec<u64> = (1..=fb.n_nodes() as u64).collect();
    let sampler = NegativeSampler::from_counts(&counts);
    bench("negative sampling (M draws)", "M-draw", 5, || {
        let mut s = 0u64;
        for _ in 0..2_000_000 {
            s = s.wrapping_add(sampler.sample(&mut rng) as u64);
        }
        std::hint::black_box(s);
        2_000_000
    });

    // L3: mean propagation (unit: propagated node-rounds).
    let d = core_decomposition(&fb);
    let core_nodes = kcore_embed::cores::subcore::k_core_nodes(&d, 25);
    let emb = Embedding::word2vec_init(core_nodes.len(), 128, &mut Rng::new(4));
    bench("mean propagation k0=25 (M node-rounds)", "M-nr", 3, || {
        let (out, stats) = propagate_mean(
            &fb,
            &d,
            25,
            &core_nodes,
            &emb,
            &PropagationParams::default(),
        );
        std::hint::black_box(out.row(0)[0]);
        (stats.nodes_propagated * stats.total_rounds.max(1)) as u64
    });

    // L3: corpus pipeline — materialized vs streaming-sharded
    // (DESIGN.md §Corpus-streaming). Same walks either way; the streamed
    // path bounds resident corpus memory with a budget and spills shards
    // to disk, and the consumer (here: a full pair sweep, the shape the
    // BatchStream trainer drives) reads them back as a stream. Reported:
    // throughput per path plus the peak-resident-bytes comparison on the
    // largest synthetic graph.
    let gh_sched = WalkSchedule::uniform(gh.n_nodes(), 5);
    let gh_params = WalkParams {
        walk_length: 30,
        seed: 11,
        threads: pool::default_threads(),
    };
    let mut materialized_bytes = 0usize;
    bench("corpus materialized github (M steps)", "M-step", 3, || {
        let c = generate_walks(&gh, &gh_sched, &gh_params);
        materialized_bytes = c.n_tokens() * 4 + (c.n_walks() + 1) * 8;
        let n: u64 = PairStream::new(&c, 2, Rng::new(12)).map(|_| 1u64).sum();
        std::hint::black_box(n);
        c.n_tokens() as u64
    });
    let budget = ShardOpts {
        shards: 16,
        budget_bytes: 8 << 20, // 8 MiB across all shards
        ..Default::default()
    };
    let mut streaming_peak = 0usize;
    let mut spilled = 0usize;
    bench("corpus streamed+spill github (M steps)", "M-step", 3, || {
        let s = generate_walk_shards(&gh, &gh_sched, &gh_params, &budget);
        streaming_peak = s.stats().peak_resident_bytes;
        spilled = s.stats().spilled_shards;
        let n: u64 = s.pair_stream(2, Rng::new(12)).map(|_| 1u64).sum();
        std::hint::black_box(n);
        s.n_tokens()
    });
    println!(
        "    corpus peak resident: materialized {:.1} MiB vs streamed {:.1} MiB \
         ({:.1}x reduction, {spilled}/{} shards spilled)",
        materialized_bytes as f64 / (1 << 20) as f64,
        streaming_peak as f64 / (1 << 20) as f64,
        materialized_bytes as f64 / streaming_peak.max(1) as f64,
        budget.shards
    );

    // L3: node2vec — the materializing wrapper (shard-native walks +
    // the into_corpus copy, i.e. what the compat API costs) vs the
    // shard-native path under a budget. Like the uniform pair above,
    // the headline is the peak-resident-bytes comparison; the steps/s
    // delta prices the materialization copy the pipeline no longer
    // pays.
    let n2v = Node2VecParams {
        p: 0.5,
        q: 2.0,
        walk_length: 30,
        seed: 11,
        threads: pool::default_threads(),
    };
    let mut n2v_materialized_bytes = 0usize;
    bench("node2vec materialized github (M steps)", "M-step", 3, || {
        let c = generate_node2vec_walks(&gh, &gh_sched, &n2v);
        n2v_materialized_bytes = c.n_tokens() * 4 + (c.n_walks() + 1) * 8;
        std::hint::black_box(c.walk(0)[0]);
        c.n_tokens() as u64
    });
    let mut n2v_peak = 0usize;
    let mut n2v_spilled = 0usize;
    bench("node2vec shard-native github (M steps)", "M-step", 3, || {
        let s = generate_node2vec_shards(&gh, &gh_sched, &n2v, &budget);
        n2v_peak = s.stats().peak_resident_bytes;
        n2v_spilled = s.stats().spilled_shards;
        std::hint::black_box(s.n_walks());
        s.n_tokens()
    });
    println!(
        "    node2vec peak resident: materialized {:.1} MiB vs shard-native {:.1} MiB \
         ({:.1}x reduction, {n2v_spilled}/{} shards spilled)",
        n2v_materialized_bytes as f64 / (1 << 20) as f64,
        n2v_peak as f64 / (1 << 20) as f64,
        n2v_materialized_bytes as f64 / n2v_peak.max(1) as f64,
        budget.shards
    );

    // Serve: top-k scan kernels over a resident store (unit: scored
    // rows). Exact blocked scan vs the 8-bit quantized candidate scan
    // with exact re-rank, both behind the ScanIndex strategy trait,
    // plus the SQ8 code-layout comparison: row-major (lanes=1) vs the
    // lane-interleaved layout the serving default uses — the scan
    // reads interleaved codes strictly sequentially per group
    // (DESIGN.md §Serving).
    {
        use kcore_embed::serve::{
            EmbeddingStore, ExactScan, Metric, QuantizedScan, ScanIndex, TopKParams,
        };
        let (sn, sdim) = (50_000usize, 128usize);
        let mut sr = Rng::new(8);
        let vecs: Vec<f32> = (0..sn * sdim).map(|_| sr.gen_f32() * 2.0 - 1.0).collect();
        let store = EmbeddingStore::from_parts(vecs, sn, sdim, vec![0; sn]);
        let params = TopKParams {
            threads: pool::default_threads(),
            ..Default::default()
        };
        let exact = ExactScan::build(&store, params.clone());
        let quant = QuantizedScan::build(&store, params.clone());
        let quant_rm = QuantizedScan::build_with_lanes(&store, params, 1);
        let queries: Vec<u32> = (0..8).map(|i| i * 601).collect();
        bench("serve exact top-10 scan (M rows)", "M-row", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = exact.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * queries.len()) as u64
        });
        bench("serve quantized top-10 scan (M rows)", "M-row", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = quant.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * queries.len()) as u64
        });
        // Same scan, codes/s headline: each scanned row reads `dim`
        // u8 codes, so codes/s = rows/s * dim.
        bench("SQ8 scan row-major codes (M codes)", "M-code", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = quant_rm.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * sdim * queries.len()) as u64
        });
        bench("SQ8 scan interleaved codes (M codes)", "M-code", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = quant.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * sdim * queries.len()) as u64
        });
        println!(
            "    SQ8 code layout: lanes {} interleaved vs row-major, \
             resident {:.1} MiB",
            quant.table().lanes(),
            quant.table().resident_bytes() as f64 / (1 << 20) as f64
        );
    }

    // L3: logistic regression fit (unit: sample-epochs).
    let (n, dim) = (4000usize, 256usize);
    let mut x = vec![0f32; n * dim];
    let mut y = vec![false; n];
    let mut r2 = Rng::new(5);
    for i in 0..n {
        y[i] = i % 2 == 0;
        for j in 0..dim {
            x[i * dim + j] = r2.gen_normal() as f32 + if y[i] && j < 4 { 1.0 } else { 0.0 };
        }
    }
    let lr_params = LogRegParams {
        epochs: 10,
        ..Default::default()
    };
    bench("logreg fit 4000x256 (M sample-epochs)", "M-se", 3, || {
        let m = LogisticRegression::fit(&x, &y, dim, &lr_params);
        std::hint::black_box(m.b);
        (n * lr_params.epochs) as u64
    });

    // RT: PJRT SGNS dispatch (unit: pairs), if artifacts are present.
    match Manifest::load(&default_artifacts_dir()) {
        Ok(manifest) => {
            let rt = Runtime::cpu().expect("pjrt cpu client");
            let small = generators::holme_kim(1000, 4, 0.4, &mut Rng::new(3));
            let params = SgnsParams::default();
            let corpus2 = generate_walks(
                &small,
                &WalkSchedule::uniform(1000, 10),
                &WalkParams {
                    walk_length: 30,
                    seed: 6,
                    threads: 4,
                },
            )
            .into_sharded();
            bench("PJRT SGNS train v1024 (M pairs)", "M-pair", 3, || {
                let r = kcore_embed::embed::trainer::train_pjrt(
                    &rt, &manifest, &corpus2, 1000, &params, 0,
                )
                .expect("pjrt train");
                std::hint::black_box(r.n_pairs);
                r.n_pairs
            });
        }
        Err(_) => {
            println!("(skipping PJRT benches: run `make artifacts` first)");
        }
    }
}
