//! `cargo bench` target: micro-benchmarks of the per-layer hot paths the
//! §Perf pass optimizes. Reports throughput per component so regressions
//! are visible without running whole experiments.
//!
//! harness = false (hand-rolled timing: warmup + repeated runs, report
//! best and mean — criterion is unavailable offline).

use std::time::Instant;

use kcore_embed::cores::core_decomposition;
use kcore_embed::embed::{batches::SgnsParams, native, sampler::NegativeSampler};
use kcore_embed::eval::logistic::{LogRegParams, LogisticRegression};
use kcore_embed::graph::generators;
use kcore_embed::propagate::{propagate_mean, PropagationParams};
use kcore_embed::runtime::{default_artifacts_dir, Manifest, Runtime};
use kcore_embed::util::rng::Rng;
use kcore_embed::walks::{
    generate_node2vec_shards, generate_node2vec_walks, generate_walk_shards, generate_walks,
    Node2VecParams, ShardOpts, WalkParams, WalkSchedule,
};

fn bench<F: FnMut() -> u64>(name: &str, unit: &str, iters: usize, mut f: F) {
    // warmup
    let _ = f();
    let mut best = f64::INFINITY;
    let mut mean = 0.0;
    let mut work = 0u64;
    for _ in 0..iters {
        let t0 = Instant::now();
        work = f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        mean += dt / iters as f64;
    }
    println!(
        "{name:<42} best {:>9.2} {unit}/s   mean {:>9.2} {unit}/s   ({} {unit}/iter)",
        work as f64 / best / 1e6,
        work as f64 / mean / 1e6,
        work
    );
}

fn main() {
    println!("hot-path micro-benchmarks (M = 1e6 units/s)\n");
    let mut rng = Rng::new(1);
    let fb = generators::facebook_like(7);
    let gh = generators::github_like(7);

    // L3: core decomposition (unit: edges).
    bench("core_decomposition facebook (M edges)", "M-edge", 5, || {
        let d = core_decomposition(&fb);
        std::hint::black_box(d.degeneracy);
        fb.n_edges() as u64
    });
    bench("core_decomposition github (M edges)", "M-edge", 3, || {
        let d = core_decomposition(&gh);
        std::hint::black_box(d.degeneracy);
        gh.n_edges() as u64
    });

    // L3: walk generation (unit: walk steps).
    let sched = WalkSchedule::uniform(fb.n_nodes(), 5);
    bench("walk generation facebook (M steps)", "M-step", 3, || {
        let c = generate_walks(
            &fb,
            &sched,
            &WalkParams {
                walk_length: 30,
                seed: 2,
                threads: kcore_embed::util::pool::default_threads(),
            },
        );
        c.n_tokens() as u64
    });

    // L3: negative sampling (unit: draws).
    let counts: Vec<u64> = (1..=fb.n_nodes() as u64).collect();
    let sampler = NegativeSampler::from_counts(&counts);
    bench("negative sampling (M draws)", "M-draw", 5, || {
        let mut s = 0u64;
        for _ in 0..2_000_000 {
            s = s.wrapping_add(sampler.sample(&mut rng) as u64);
        }
        std::hint::black_box(s);
        2_000_000
    });

    // L3: native SGNS training (unit: pairs).
    let small = generators::holme_kim(1000, 4, 0.4, &mut Rng::new(3));
    let corpus = generate_walks(
        &small,
        &WalkSchedule::uniform(1000, 5),
        &WalkParams {
            walk_length: 20,
            seed: 3,
            threads: 4,
        },
    );
    let params = SgnsParams::default();
    bench("native SGNS train (M pairs)", "M-pair", 3, || {
        let r = native::train_native(&corpus, 1000, &params);
        std::hint::black_box(r.mean_loss);
        r.n_pairs
    });

    // L3: mean propagation (unit: propagated node-rounds).
    let d = core_decomposition(&fb);
    let core_nodes = kcore_embed::cores::subcore::k_core_nodes(&d, 25);
    let emb = kcore_embed::embed::Embedding::word2vec_init(
        core_nodes.len(),
        128,
        &mut Rng::new(4),
    );
    bench("mean propagation k0=25 (M node-rounds)", "M-nr", 3, || {
        let (out, stats) = propagate_mean(
            &fb,
            &d,
            25,
            &core_nodes,
            &emb,
            &PropagationParams::default(),
        );
        std::hint::black_box(out.row(0)[0]);
        (stats.nodes_propagated * stats.total_rounds.max(1)) as u64
    });

    // L3: corpus pipeline — materialized vs streaming-sharded
    // (DESIGN.md §Corpus-streaming). Same walks either way; the streamed
    // path bounds resident corpus memory with a budget and spills shards
    // to disk, and the consumer (here: a full pair sweep, the shape the
    // BatchStream trainer drives) reads them back as a stream. Reported:
    // throughput per path plus the peak-resident-bytes comparison on the
    // largest synthetic graph.
    let gh_sched = WalkSchedule::uniform(gh.n_nodes(), 5);
    let gh_params = WalkParams {
        walk_length: 30,
        seed: 11,
        threads: kcore_embed::util::pool::default_threads(),
    };
    let mut materialized_bytes = 0usize;
    bench("corpus materialized github (M steps)", "M-step", 3, || {
        let c = generate_walks(&gh, &gh_sched, &gh_params);
        materialized_bytes = c.n_tokens() * 4 + (c.n_walks() + 1) * 8;
        let n: u64 = kcore_embed::walks::PairStream::new(&c, 2, Rng::new(12))
            .map(|_| 1u64)
            .sum();
        std::hint::black_box(n);
        c.n_tokens() as u64
    });
    let budget = ShardOpts {
        shards: 16,
        budget_bytes: 8 << 20, // 8 MiB across all shards
        ..Default::default()
    };
    let mut streaming_peak = 0usize;
    let mut spilled = 0usize;
    bench("corpus streamed+spill github (M steps)", "M-step", 3, || {
        let s = generate_walk_shards(&gh, &gh_sched, &gh_params, &budget);
        streaming_peak = s.stats().peak_resident_bytes;
        spilled = s.stats().spilled_shards;
        let n: u64 = s.pair_stream(2, Rng::new(12)).map(|_| 1u64).sum();
        std::hint::black_box(n);
        s.n_tokens()
    });
    println!(
        "    corpus peak resident: materialized {:.1} MiB vs streamed {:.1} MiB \
         ({:.1}x reduction, {spilled}/{} shards spilled)",
        materialized_bytes as f64 / (1 << 20) as f64,
        streaming_peak as f64 / (1 << 20) as f64,
        materialized_bytes as f64 / streaming_peak.max(1) as f64,
        budget.shards
    );

    // L3: node2vec — the materializing wrapper (shard-native walks +
    // the into_corpus copy, i.e. what the compat API costs) vs the
    // shard-native path under a budget. Like the uniform pair above,
    // the headline is the peak-resident-bytes comparison; the steps/s
    // delta prices the materialization copy the pipeline no longer
    // pays.
    let n2v = Node2VecParams {
        p: 0.5,
        q: 2.0,
        walk_length: 30,
        seed: 11,
        threads: kcore_embed::util::pool::default_threads(),
    };
    let mut n2v_materialized_bytes = 0usize;
    bench("node2vec materialized github (M steps)", "M-step", 3, || {
        let c = generate_node2vec_walks(&gh, &gh_sched, &n2v);
        n2v_materialized_bytes = c.n_tokens() * 4 + (c.n_walks() + 1) * 8;
        std::hint::black_box(c.walk(0)[0]);
        c.n_tokens() as u64
    });
    let mut n2v_peak = 0usize;
    let mut n2v_spilled = 0usize;
    bench("node2vec shard-native github (M steps)", "M-step", 3, || {
        let s = generate_node2vec_shards(&gh, &gh_sched, &n2v, &budget);
        n2v_peak = s.stats().peak_resident_bytes;
        n2v_spilled = s.stats().spilled_shards;
        std::hint::black_box(s.n_walks());
        s.n_tokens()
    });
    println!(
        "    node2vec peak resident: materialized {:.1} MiB vs shard-native {:.1} MiB \
         ({:.1}x reduction, {n2v_spilled}/{} shards spilled)",
        n2v_materialized_bytes as f64 / (1 << 20) as f64,
        n2v_peak as f64 / (1 << 20) as f64,
        n2v_materialized_bytes as f64 / n2v_peak.max(1) as f64,
        budget.shards
    );

    // Serve: top-k scan kernels over a resident store (unit: scored
    // rows). Exact blocked scan vs the 8-bit quantized candidate scan
    // with exact re-rank, both behind the ScanIndex strategy trait,
    // plus the SQ8 code-layout comparison: row-major (lanes=1) vs the
    // lane-interleaved layout the serving default uses — the scan
    // reads interleaved codes strictly sequentially per group
    // (DESIGN.md §Serving).
    {
        use kcore_embed::serve::{
            EmbeddingStore, ExactScan, Metric, QuantizedScan, ScanIndex, TopKParams,
        };
        let (sn, sdim) = (50_000usize, 128usize);
        let mut sr = Rng::new(8);
        let vecs: Vec<f32> = (0..sn * sdim).map(|_| sr.gen_f32() * 2.0 - 1.0).collect();
        let store = EmbeddingStore::from_parts(vecs, sn, sdim, vec![0; sn]);
        let params = TopKParams {
            threads: kcore_embed::util::pool::default_threads(),
            ..Default::default()
        };
        let exact = ExactScan::build(&store, params.clone());
        let quant = QuantizedScan::build(&store, params.clone());
        let quant_rm = QuantizedScan::build_with_lanes(&store, params, 1);
        let queries: Vec<u32> = (0..8).map(|i| i * 601).collect();
        bench("serve exact top-10 scan (M rows)", "M-row", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = exact.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * queries.len()) as u64
        });
        bench("serve quantized top-10 scan (M rows)", "M-row", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = quant.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * queries.len()) as u64
        });
        // Same scan, codes/s headline: each scanned row reads `dim`
        // u8 codes, so codes/s = rows/s * dim.
        bench("SQ8 scan row-major codes (M codes)", "M-code", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = quant_rm.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * sdim * queries.len()) as u64
        });
        bench("SQ8 scan interleaved codes (M codes)", "M-code", 3, || {
            let mut acc = 0u32;
            for &q in &queries {
                let hits = quant.top_k_node(&store, q, 10, Metric::Cosine);
                acc ^= hits[0].0;
            }
            std::hint::black_box(acc);
            (sn * sdim * queries.len()) as u64
        });
        println!(
            "    SQ8 code layout: lanes {} interleaved vs row-major, \
             resident {:.1} MiB",
            quant.table().lanes(),
            quant.table().resident_bytes() as f64 / (1 << 20) as f64
        );
    }

    // L3: logistic regression fit (unit: sample-epochs).
    let (n, dim) = (4000usize, 256usize);
    let mut x = vec![0f32; n * dim];
    let mut y = vec![false; n];
    let mut r2 = Rng::new(5);
    for i in 0..n {
        y[i] = i % 2 == 0;
        for j in 0..dim {
            x[i * dim + j] = r2.gen_normal() as f32 + if y[i] && j < 4 { 1.0 } else { 0.0 };
        }
    }
    let lr_params = LogRegParams {
        epochs: 10,
        ..Default::default()
    };
    bench("logreg fit 4000x256 (M sample-epochs)", "M-se", 3, || {
        let m = LogisticRegression::fit(&x, &y, dim, &lr_params);
        std::hint::black_box(m.b);
        (n * lr_params.epochs) as u64
    });

    // RT: PJRT SGNS dispatch (unit: pairs), if artifacts are present.
    match Manifest::load(&default_artifacts_dir()) {
        Ok(manifest) => {
            let rt = Runtime::cpu().expect("pjrt cpu client");
            let corpus2 = generate_walks(
                &small,
                &WalkSchedule::uniform(1000, 10),
                &WalkParams {
                    walk_length: 30,
                    seed: 6,
                    threads: 4,
                },
            )
            .into_sharded();
            bench("PJRT SGNS train v1024 (M pairs)", "M-pair", 3, || {
                let r = kcore_embed::embed::trainer::train_pjrt(
                    &rt, &manifest, &corpus2, 1000, &params, 0,
                )
                .expect("pjrt train");
                std::hint::black_box(r.n_pairs);
                r.n_pairs
            });
        }
        Err(_) => {
            println!("(skipping PJRT benches: run `make artifacts` first)");
        }
    }
}
