//! Quickstart: the whole system in ~40 lines.
//!
//! Generates a small clustered graph, embeds it with CoreWalk
//! (core-adaptive random walks) on the PJRT backend if artifacts exist
//! (native fallback otherwise), and evaluates link prediction.
//!
//! Run: `cargo run --release --example quickstart`

use kcore_embed::coordinator::{run_pipeline, Backend, Embedder, PipelineConfig};
use kcore_embed::eval::{evaluate_link_prediction, split_edges};
use kcore_embed::graph::generators;
use kcore_embed::runtime::{default_artifacts_dir, Manifest, Runtime};
use kcore_embed::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A graph: Holme-Kim power-law-cluster, 800 nodes.
    let g = generators::holme_kim(800, 4, 0.5, &mut Rng::new(42));
    println!("graph: {} nodes, {} edges", g.n_nodes(), g.n_edges());

    // 2. Hold out 10% of edges for link prediction.
    let mut rng = Rng::new(1);
    let split = split_edges(&g, 0.10, &mut rng);

    // 3. Configure the pipeline: CoreWalk walks, PJRT backend if built.
    let runtime = match Manifest::load(&default_artifacts_dir()) {
        Ok(m) => Some((Runtime::cpu()?, m)),
        Err(_) => {
            eprintln!("(artifacts not found — run `make artifacts`; using native backend)");
            None
        }
    };
    let cfg = PipelineConfig {
        embedder: Embedder::CoreWalk,
        backend: if runtime.is_some() {
            Backend::Pjrt
        } else {
            Backend::Native
        },
        walks_per_node: 10,
        seed: 42,
        ..Default::default()
    };

    // 4. Run: decompose → walk → train → (no propagation: k0 = None).
    let rt_ref = runtime.as_ref().map(|(r, m)| (r, m));
    let out = run_pipeline(&split.train_graph, &cfg, rt_ref)?;
    println!(
        "embedded {} nodes in {:.2}s (degeneracy {}, {} walks, {} pairs)",
        out.embedding.n(),
        out.total_secs(),
        out.degeneracy,
        out.n_walks,
        out.n_pairs
    );

    // 5. Evaluate.
    let res = evaluate_link_prediction(&g, &split.removed, &out.embedding, &mut rng);
    println!(
        "link prediction: F1 {:.1}%  AUC {:.3}  (test size {})",
        res.f1 * 100.0,
        res.auc,
        res.n_test
    );
    Ok(())
}
