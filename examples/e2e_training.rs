//! END-TO-END DRIVER (the DESIGN.md validation workload).
//!
//! Exercises every layer on a real small workload and proves they
//! compose: the facebook-like graph (4039 nodes / 88k edges), 10% of
//! edges held out, embedded through the full paper pipeline on the PJRT
//! backend — AOT HLO artifact (jax scan + Pallas SGNS kernel) loaded and
//! driven from rust with device-resident state — logging the SGNS loss
//! curve, then mean-propagated and scored on link prediction. The run is
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example e2e_training`

use kcore_embed::coordinator::pipeline::{PHASE_DECOMP, PHASE_PROP, PHASE_TRAIN, PHASE_WALKS};
use kcore_embed::coordinator::{run_pipeline, Backend, Embedder, PipelineConfig};
use kcore_embed::cores::core_decomposition;
use kcore_embed::eval::{evaluate_link_prediction, split_edges};
use kcore_embed::graph::generators;
use kcore_embed::runtime::{default_artifacts_dir, Manifest, Runtime};
use kcore_embed::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let runtime = Runtime::cpu()?;
    println!("pjrt platform: {}", runtime.platform());

    let g = generators::facebook_like(7);
    let d = core_decomposition(&g);
    println!(
        "workload: facebook-like graph — {} nodes, {} edges, degeneracy {}",
        g.n_nodes(),
        g.n_edges(),
        d.degeneracy
    );

    let mut rng = Rng::new(11);
    let split = split_edges(&g, 0.10, &mut rng);
    println!(
        "held out {} edges (10%); training on {} edges",
        split.removed.len(),
        split.train_graph.n_edges()
    );

    for (label, embedder, k0) in [
        ("CoreWalk (full graph)", Embedder::CoreWalk, None),
        ("DeepWalk on 25-core + propagation", Embedder::DeepWalk, Some(25)),
    ] {
        println!("\n=== {label} ===");
        let cfg = PipelineConfig {
            embedder,
            backend: Backend::Pjrt,
            k0,
            walks_per_node: 8, // reduced n for a minutes-scale driver
            seed: 11,
            loss_poll: 25, // log the loss curve every 25 dispatches
            ..Default::default()
        };
        let out = run_pipeline(&split.train_graph, &cfg, Some((&runtime, &manifest)))?;
        println!(
            "core size {} / {} nodes; {} walks -> {} tokens -> {} pairs",
            out.core_size,
            g.n_nodes(),
            out.n_walks,
            out.n_tokens,
            out.n_pairs
        );
        println!(
            "phases: decomp {:.2}s | walks {:.2}s | train {:.2}s | prop {:.2}s | total {:.2}s",
            out.timer.secs(PHASE_DECOMP),
            out.timer.secs(PHASE_WALKS),
            out.timer.secs(PHASE_TRAIN),
            out.timer.secs(PHASE_PROP),
            out.total_secs()
        );
        if !out.loss_curve.is_empty() {
            println!("SGNS loss curve (device stats row):");
            for p in &out.loss_curve {
                println!("  pairs {:>10}  mean loss {:.4}", p.pairs, p.mean_loss);
            }
        }
        let res = evaluate_link_prediction(&g, &split.removed, &out.embedding, &mut rng);
        println!(
            "link prediction: F1 {:.2}%  precision {:.2}%  recall {:.2}%  AUC {:.3}",
            res.f1 * 100.0,
            res.precision * 100.0,
            res.recall * 100.0,
            res.auc
        );
    }
    Ok(())
}
