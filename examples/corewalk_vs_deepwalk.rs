//! The paper's headline comparison (§2.1 / Table 3): CoreWalk vs
//! DeepWalk on the facebook-like graph — walk-count reduction, speedup,
//! and F1 parity, plus the walks-per-core-index schedule (Fig 1 data).
//!
//! Run: `cargo run --release --example corewalk_vs_deepwalk`

use kcore_embed::coordinator::{run_pipeline, Backend, Embedder, PipelineConfig};
use kcore_embed::cores::core_decomposition;
use kcore_embed::eval::{evaluate_link_prediction, split_edges};
use kcore_embed::graph::generators;
use kcore_embed::util::rng::Rng;
use kcore_embed::walks::corewalk;

fn main() -> anyhow::Result<()> {
    let g = generators::facebook_like(7);
    let d = core_decomposition(&g);
    println!(
        "facebook-like: {} nodes, {} edges, degeneracy {}",
        g.n_nodes(),
        g.n_edges(),
        d.degeneracy
    );

    // Eq. 13 schedule, paper's n = 15 (Fig 1).
    println!("\nwalks per node by core index (n = 15):");
    for (k, n) in corewalk::walks_per_core(&d, 15).iter().step_by(8) {
        println!("  core {k:>3}: {n:>2} walks  {}", "*".repeat(*n as usize));
    }
    println!(
        "corpus reduction vs uniform: {:.1}% of the walks remain",
        corewalk::walk_reduction(&d, 15) * 100.0
    );

    let mut rng = Rng::new(3);
    let split = split_edges(&g, 0.10, &mut rng);
    for embedder in [Embedder::DeepWalk, Embedder::CoreWalk] {
        let cfg = PipelineConfig {
            embedder: embedder.clone(),
            backend: Backend::Native,
            walks_per_node: 10,
            seed: 3,
            ..Default::default()
        };
        let out = run_pipeline(&split.train_graph, &cfg, None)?;
        let res = evaluate_link_prediction(&g, &split.removed, &out.embedding, &mut Rng::new(4));
        println!(
            "\n{:<9}  walks {:>6}  pairs {:>9}  time {:>6.2}s  F1 {:.2}%  AUC {:.3}",
            embedder.name(),
            out.n_walks,
            out.n_pairs,
            out.total_secs(),
            res.f1 * 100.0,
            res.auc
        );
    }
    Ok(())
}
