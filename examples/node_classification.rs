//! Node-classification extension (§3.1.2 "additional experiments"): the
//! paper reports walk-based embeddings are weak on this task; we
//! reproduce both the task and the finding on an SBM with planted
//! community labels — community structure IS recoverable (well above
//! chance) but far from supervised-GNN territory.
//!
//! Run: `cargo run --release --example node_classification`

use kcore_embed::coordinator::{run_pipeline, Backend, Embedder, PipelineConfig};
use kcore_embed::eval::nodeclass::evaluate_node_classification;
use kcore_embed::graph::generators;
use kcore_embed::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(21);
    let (g, labels) =
        generators::stochastic_block_model(&[150, 150, 150, 150], 0.12, 0.01, &mut rng);
    let n_classes = 4;
    println!(
        "SBM: {} nodes, {} edges, {n_classes} planted communities",
        g.n_nodes(),
        g.n_edges()
    );

    for embedder in [Embedder::DeepWalk, Embedder::CoreWalk] {
        let cfg = PipelineConfig {
            embedder: embedder.clone(),
            backend: Backend::Native,
            walks_per_node: 10,
            sgns: kcore_embed::embed::SgnsParams {
                dim: 64,
                ..Default::default()
            },
            seed: 21,
            ..Default::default()
        };
        let out = run_pipeline(&g, &cfg, None)?;
        let res = evaluate_node_classification(&out.embedding, &labels, n_classes, &mut rng);
        println!(
            "{:<9}  macro-F1 {:.2}%  accuracy {:.2}%  ({} test nodes, {:.1}s)",
            embedder.name(),
            res.macro_f1 * 100.0,
            res.accuracy * 100.0,
            res.n_test,
            out.total_secs()
        );
    }
    println!("\n(chance accuracy would be 25%)");
    Ok(())
}
