//! Mean-embedding-propagation scaling (§2.2 / Fig 2): sweep the initial
//! core index k0 and watch total time collapse while F1 degrades only
//! moderately — the paper's central trade-off.
//!
//! Run: `cargo run --release --example propagation_scaling`

use kcore_embed::coordinator::pipeline::{PHASE_DECOMP, PHASE_PROP};
use kcore_embed::coordinator::{run_pipeline, Backend, PipelineConfig};
use kcore_embed::eval::{evaluate_link_prediction, split_edges};
use kcore_embed::graph::generators;
use kcore_embed::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let g = generators::facebook_like(7);
    let mut rng = Rng::new(9);
    let split = split_edges(&g, 0.10, &mut rng);

    let base = PipelineConfig {
        backend: Backend::Native,
        walks_per_node: 10,
        seed: 9,
        ..Default::default()
    };

    // Baseline row.
    let out = run_pipeline(&split.train_graph, &base, None)?;
    let res = evaluate_link_prediction(&g, &split.removed, &out.embedding, &mut Rng::new(1));
    let t_base = out.total_secs();
    println!(
        "{:<12} core {:>5}  total {:>6.2}s  decomp {:>5.2}s  prop {:>5.2}s  F1 {:>6.2}%",
        "DeepWalk",
        out.core_size,
        t_base,
        0.0,
        0.0,
        res.f1 * 100.0
    );

    for k0 in [9u32, 25, 49, 73, 97] {
        let cfg = PipelineConfig {
            k0: Some(k0),
            ..base.clone()
        };
        let out = run_pipeline(&split.train_graph, &cfg, None)?;
        let res = evaluate_link_prediction(&g, &split.removed, &out.embedding, &mut Rng::new(1));
        println!(
            "{:<12} core {:>5}  total {:>6.2}s  decomp {:>5.2}s  prop {:>5.2}s  F1 {:>6.2}%  speedup x{:.1}",
            format!("{k0}-core (Dw)"),
            out.core_size,
            out.total_secs(),
            out.timer.secs(PHASE_DECOMP),
            out.timer.secs(PHASE_PROP),
            res.f1 * 100.0,
            t_base / out.total_secs()
        );
    }
    println!("\nExpected shape (paper Table 2): total time collapses with k0,");
    println!("decomposition+propagation stay sub-second, F1 drop stays bounded.");
    Ok(())
}
